"""Execution-plan layer: hashed open key domains (exact collision
accounting, dense-equivalence), on-device window fan-out vs the host
baseline (bit-for-bit), and windowed group-mode reducers — all through the
same ``ExecutionPlan`` entry point the batch engine uses."""

import json
from collections import defaultdict

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic container: seeded-sampling shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import MemoryStore, MetadataStore
from repro.engine import ExecutionPlan, KeySpace, ReduceSpec, WindowSpec
from repro.engine.stages import INT32_MAX, device_hash
from repro.pipeline import Pipeline, Windowing
from repro.streaming import (SlidingWindows, StreamSource,
                             StreamingCoordinator, TumblingWindows)

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

W = 4  # workers in every plan below


def _map_fn(shard):
    return (shard[:, 0].astype(jnp.int32), shard[:, 1], shard[:, 2] > 0)


def _shards(keys, vals):
    n = -(-len(keys) // W) * W
    rows = np.zeros((n, 3), np.float32)    # [key, value, valid]; pad invalid
    rows[:len(keys), 0] = keys
    rows[:len(keys), 1] = vals
    rows[:len(keys), 2] = 1.0
    return rows.reshape(W, n // W, 3)


def _run_hashed(keys, vals, num_buckets):
    plan = ExecutionPlan(KeySpace.hashed(num_buckets), ReduceSpec("aggregate"),
                         n_workers=W)
    out, stats = plan.compile(_map_fn).run(_shards(keys, vals))
    return np.asarray(out), stats


def _bucket_of(keys, num_buckets):
    return np.asarray(device_hash(jnp.asarray(keys, jnp.int32))
                      % np.uint32(num_buckets)).astype(int)


# ---------------------------------------------------------------------------
# Hashed key space: collision accounting is exact
# ---------------------------------------------------------------------------

keys_vals = st.integers(8, 80).flatmap(
    lambda n: st.tuples(
        st.lists(st.integers(0, 1 << 20), min_size=n, max_size=n),
        st.lists(st.integers(1, 9), min_size=n, max_size=n)))


@given(keys_vals, st.integers(4, 64))
def test_hashed_collision_accounting_is_exact(kv, num_buckets):
    keys, vals = kv
    out, stats = _run_hashed(keys, vals, num_buckets)
    buckets = _bucket_of(keys, num_buckets)
    per_bucket_distinct = np.zeros(num_buckets, int)
    for b in set(buckets.tolist()):
        per_bucket_distinct[b] = len(
            {k for k, kb in zip(keys, buckets) if kb == b})
    want = np.maximum(per_bucket_distinct - 1, 0)
    got = np.asarray(stats.bucket_collisions)
    assert np.array_equal(got, want)
    assert int(np.asarray(stats.collisions)) == int(want.sum())
    # mass conservation: hashing never loses records, only key identity
    assert out[:num_buckets].sum() == float(sum(vals))


@given(keys_vals, st.integers(4, 64))
def test_hashed_equals_dense_when_domain_fits(kv, num_buckets):
    """With keys already in [0, num_buckets) a dense plan is exact; the
    hashed plan must agree bucket-for-bucket whenever no two present keys
    collide (and always in total mass)."""
    keys, vals = kv
    keys = [k % num_buckets for k in keys]
    dense_plan = ExecutionPlan(KeySpace.dense(num_buckets),
                               ReduceSpec("aggregate"), n_workers=W)
    dense, _ = dense_plan.compile(_map_fn).run(_shards(keys, vals))
    dense = np.asarray(dense)
    hashed, stats = _run_hashed(keys, vals, num_buckets)
    assert hashed[:num_buckets].sum() == dense[:num_buckets].sum()
    if int(np.asarray(stats.collisions)) == 0:
        buckets = _bucket_of(keys, num_buckets)
        for k in set(keys):
            b = buckets[keys.index(k)]
            assert hashed[b] == dense[k], (k, b)


def test_hashed_group_mode_end_to_end():
    """Open key domains compose with the grouping shuffle: keys hash into
    buckets before the fixed-capacity exchange."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 1 << 20, 200).tolist()
    vals = rng.integers(1, 5, 200).tolist()
    plan = ExecutionPlan(KeySpace.hashed(32),
                         ReduceSpec("group", reduce_fn="sum", capacity=512),
                         n_workers=W)
    (gk, gv, gvalid), stats = plan.compile(_map_fn).run(_shards(keys, vals))
    got = {int(k): float(v) for k, v, ok in
           zip(np.asarray(gk), np.asarray(gv), np.asarray(gvalid)) if ok}
    buckets = _bucket_of(keys, 32)
    want = defaultdict(float)
    for b, v in zip(buckets, vals):
        want[int(b)] += v
    assert got == dict(want)
    assert int(np.asarray(stats.dropped)) == 0
    assert int(np.asarray(stats.collisions)) > 0   # 200 keys into 32 buckets


# ---------------------------------------------------------------------------
# On-device window fan-out == host fan-out, bit for bit
# ---------------------------------------------------------------------------

def _synth_events(n=3000, n_keys=12, span=300.0, seed=3):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.uniform(0, span, n))
    keys = rng.integers(0, n_keys, n)
    vals = rng.integers(0, 50, n).astype(float)
    return [(float(t), f"k{k}", float(v))
            for t, k, v in zip(ts, keys, vals)]


def _run_stream(events, job_id, *, window_size, window_slide=None,
                n_slots=8, aggregation="count", mode=None, reduce_fn=None,
                capacity=0, fanout="device", num_buckets=16,
                key_space="dense"):
    w = (Windowing.sliding(window_size, window_slide) if window_slide
         else Windowing.tumbling(window_size))
    spec = reduce_fn if mode == "group" else aggregation
    built = (Pipeline.from_source(batch_records=256).key_by().window(w)
             .reduce(spec, mode=mode or "aggregate", capacity=capacity)
             .sink("stream-output/")
             .build(num_buckets=num_buckets, n_workers=W, n_slots=n_slots,
                    key_space=key_space, fanout=fanout, batch_records=256,
                    job_id=job_id))
    store = MemoryStore()
    coord = StreamingCoordinator(store, MetadataStore(), program=built)
    report = coord.run_stream(
        StreamSource.from_records(events, batch_records=256))
    out = {}
    for m in store.list_objects(f"stream-output/{job_id}/"):
        out[m.key.rsplit("/", 1)[1]] = store.get(m.key)
    return out, report


def test_device_fanout_matches_host_fanout_bitwise():
    """slide = size/4 → every record replicates into 4 windows.  The
    device path ships each record once and fans out on-chip; outputs must
    be byte-identical to the PR 1 host event × window expansion."""
    events = _synth_events()
    win = dict(window_size=50.0, window_slide=12.5, n_slots=8,
               aggregation="sum")
    host, rh = _run_stream(events, "h", fanout="host", **win)
    dev, rd = _run_stream(events, "d", fanout="device", **win)
    assert host and host == dev               # bit-for-bit, every window
    assert rh.records_expanded == rd.records_expanded == 4 * len(events)
    assert rh.late_dropped == rd.late_dropped
    assert rh.windows_emitted == rd.windows_emitted


def test_device_fanout_counts_late_pairs_like_host():
    """Out-of-order events past the watermark are masked on-chip and
    counted identically to the host path's per-pair drops."""
    rng = np.random.default_rng(11)
    events = [(float(t), "k", 1.0) for t in rng.uniform(0, 400.0, 2000)]
    win = dict(window_size=40.0, window_slide=10.0, n_slots=8,
               aggregation="count")
    host, rh = _run_stream(events, "lh", fanout="host", **win)
    dev, rd = _run_stream(events, "ld", fanout="device", **win)
    assert rh.late_dropped == rd.late_dropped > 0
    assert host == dev


def test_device_fanout_epoch_timestamps_match_host():
    """Unix-epoch event times put absolute window indices (~1.1e8) far past
    float32's exact-integer range; the per-batch rebase must keep the wire
    exact and bit-identical to the host path."""
    rng = np.random.default_rng(23)
    t0 = 1.7e9
    events = [(float(t0 + t), f"k{int(k)}", float(v)) for t, k, v in
              zip(np.sort(rng.uniform(0, 600.0, 1500)),
                  rng.integers(0, 8, 1500), rng.integers(0, 30, 1500))]
    win = dict(window_size=60.0, window_slide=15.0, n_slots=8,
               aggregation="sum")
    host, rh = _run_stream(events, "eh", fanout="host", **win)
    dev, rd = _run_stream(events, "ed", fanout="device", **win)
    assert host and host == dev
    assert rh.records_expanded == rd.records_expanded == 4 * len(events)
    assert rh.late_dropped == rd.late_dropped


def test_device_fanout_mid_batch_ring_full_matches_host():
    """A low-rate sliding batch spanning more windows than the ring holds
    forces mid-batch folds; the device path must split the triggering
    record's window coverage around the fold and still match the host
    baseline byte for byte."""
    events = [(float(i), f"k{i % 3}", 1.0) for i in range(100)]
    win = dict(window_size=4.0, window_slide=2.0, n_slots=4,
               aggregation="count")
    host, rh = _run_stream(events, "mh", fanout="host", **win)
    dev, rd = _run_stream(events, "md", fanout="device", **win)
    assert len(host) == 51 and host == dev
    assert rh.records_expanded == rd.records_expanded == 2 * len(events)
    assert rh.late_dropped == rd.late_dropped == 0


@given(st.floats(5.0, 500.0, allow_nan=False),
       st.integers(1, 4), st.floats(0.0, 50.0, allow_nan=False))
def test_min_live_index_agrees_with_is_late(size, divisor, watermark):
    """The device late-masking bound and the host's is_late predicate must
    agree exactly, including on window boundaries."""
    for assigner in (TumblingWindows(size),
                     SlidingWindows(size, size / divisor)):
        lo = assigner.min_live_index(watermark)
        assert assigner.window(lo).end > watermark
        assert assigner.window(lo - 1).end <= watermark


def test_min_live_index_exact_on_boundary():
    a = TumblingWindows(10.0)
    # watermark exactly at window 0's end: window 0 is late, window 1 live
    assert a.min_live_index(10.0) == 1
    assert a.min_live_index(10.0 - 1e-9) == 0
    assert a.min_live_index(float("-inf")) == -(2 ** 31)


# ---------------------------------------------------------------------------
# Carry handoff: finalized aggregates → the next plan's wire rows
# ---------------------------------------------------------------------------

def test_carry_handoff_rows_relabels_and_masks():
    """The handoff stage body: occupied buckets become device-fan-out wire
    rows with the relabeled key and the kind-selected value; empty or
    unlabeled buckets come back invalid, and the output pads to the
    destination's wire capacity."""
    from repro.engine.stages import carry_handoff_rows
    agg = jnp.asarray([[6.0, 2.0],      # bucket 0: sum 6, count 2
                       [0.0, 0.0],      # bucket 1: empty
                       [5.0, 1.0],      # bucket 2: occupied
                       [9.0, 3.0]])     # bucket 3: occupied but unlabeled
    relabel = jnp.asarray([7, 4, 1, -1], jnp.int32)
    for kind, want in (("count", [2.0, 1.0]), ("sum", [6.0, 5.0]),
                       ("mean", [3.0, 5.0])):
        rows = np.asarray(carry_handoff_rows(
            agg, relabel, jnp.float32(11.0), jnp.float32(2.0), kind, 8))
        assert rows.shape == (8, 5)
        valid = rows[:, 4] > 0
        assert valid.tolist() == [True, False, True, False] + [False] * 4
        assert rows[valid, 2].tolist() == [7.0, 1.0]      # relabeled keys
        assert rows[valid, 3].tolist() == want
        assert set(rows[valid, 0]) == {11.0}              # last_window
        assert set(rows[valid, 1]) == {2.0}               # n_windows


def test_compiled_handoff_rows_feed_next_plan():
    """End-to-end through the compiled plans: fold records into plan A,
    hand its finalized slot to plan B via ``handoff_rows`` + ``step``, and
    read the re-windowed aggregate back from B's carry."""
    nb = 8
    plan = ExecutionPlan(KeySpace.dense(nb), ReduceSpec("aggregate"),
                         n_workers=W,
                         window=WindowSpec(size=10.0, n_slots=4))
    a = plan.compile()
    b = plan.compile()
    ca, cb = a.init_carry(), b.init_carry()
    rows = np.zeros((W, 2, 5), np.float32)
    rows[0, 0] = (3, 1, 2, 5.0, 1.0)    # window 3, key 2, value 5
    rows[0, 1] = (3, 1, 2, 7.0, 1.0)    # window 3, key 2, value 7
    rows[1, 0] = (3, 1, 4, 1.0, 1.0)    # window 3, key 4
    ca, _ = a.step(rows, ca, -(2 ** 31))
    relabel = jnp.arange(nb, dtype=jnp.int32)       # identity re-key
    handoff = a.handoff_rows(ca, 3, relabel, 1, 1, "sum", W * 2)
    assert handoff.shape == (W, 2, 5)               # vmap wire layout
    cb, _ = b.step(handoff, cb, -(2 ** 31))
    agg = b.read_slot(cb, 1)                        # window 1 of plan B
    assert agg[2].tolist() == [12.0, 1.0]           # sum 12 as ONE record
    assert agg[4].tolist() == [1.0, 1.0]
    assert np.all(agg[[0, 1, 3, 5, 6, 7]] == 0)


# ---------------------------------------------------------------------------
# Windowed group mode: arbitrary reduce_fn through the plan layer
# ---------------------------------------------------------------------------

def _median_reduce(keys, values, starts):
    """A genuinely non-algebraic reducer: per-group median over the full
    value list (the reduce the combiner/reduce_scatter path cannot fuse)."""
    n = keys.shape[0]
    valid = keys != INT32_MAX
    seg = jnp.cumsum(starts) - 1
    seg = jnp.where(valid, seg, n)
    order = jnp.lexsort((values, seg))
    v = values[order]
    s = seg[order]
    counts = jnp.zeros((n + 1,), jnp.int32).at[s].add(1)[:n]
    offsets = jnp.cumsum(counts) - counts
    lo = jnp.clip(offsets + (counts - 1) // 2, 0, n - 1)
    hi = jnp.clip(offsets + counts // 2, 0, n - 1)
    med = (v[lo] + v[hi]) / 2.0
    group_keys = jnp.full((n + 1,), -1, jnp.int32).at[s].max(
        jnp.where(valid, keys, -1))[:n]
    group_valid = (group_keys >= 0) & (counts > 0)
    return group_keys, jnp.where(group_valid, med, 0.0), group_valid


def test_streaming_group_mode_median_end_to_end():
    """A streaming job with a non-algebraic reduce_fn runs through the same
    ExecutionPlan entry point as batch mapreduce: records buffer on-device
    per (worker, window slot) across micro-batches and reduce over each
    key's full value list at finalization."""
    events = _synth_events(n=2000, n_keys=6, span=200.0, seed=5)
    out, report = _run_stream(events, "med", window_size=50.0, mode="group",
                              reduce_fn=_median_reduce, capacity=1024,
                              n_slots=4)
    assert report.error is None and report.capacity_dropped == 0
    oracle = defaultdict(lambda: defaultdict(list))
    for ts, k, v in events:
        oracle[int(ts // 50.0)][k].append(v)
    assert len(out) == len(oracle)
    for widx, per_key in oracle.items():
        got = dict(json.loads(line) for line in
                   out[f"window-{widx * 50.0:.3f}-{(widx + 1) * 50.0:.3f}"]
                   .splitlines())
        want = {k: float(np.median(vs)) for k, vs in per_key.items()}
        assert got == pytest.approx(want)


def test_streaming_group_mode_builtin_kind_sliding():
    """Built-in segment kinds work too, across overlapping windows."""
    events = _synth_events(n=1500, n_keys=5, span=150.0, seed=7)
    out, report = _run_stream(events, "gmax", window_size=40.0,
                              window_slide=20.0, mode="group",
                              reduce_fn="max", capacity=1024, n_slots=6)
    assert report.error is None
    assert report.records_expanded == 2 * len(events)
    oracle = defaultdict(lambda: defaultdict(float))
    assigner = SlidingWindows(40.0, 20.0)
    for ts, k, v in events:
        for widx in assigner.assign(ts):
            oracle[widx][k] = max(oracle[widx][k], v)
    for widx, per_key in oracle.items():
        w = assigner.window(widx)
        got = dict(json.loads(line) for line in
                   out[f"window-{w.start:.3f}-{w.end:.3f}"].splitlines())
        assert got == pytest.approx(dict(per_key))


def test_streaming_group_capacity_overflow_is_counted():
    events = [(float(i) % 10.0, f"k{i % 3}", 1.0) for i in range(600)]
    out, report = _run_stream(events, "ovf", window_size=100.0, mode="group",
                              reduce_fn="count", capacity=8, n_slots=2)
    assert report.capacity_dropped > 0
    total = sum(json.loads(line)[1]
                for blob in out.values() for line in blob.splitlines())
    assert total + report.capacity_dropped == len(events)


# ---------------------------------------------------------------------------
# Hashed open key domains, streaming end to end
# ---------------------------------------------------------------------------

def test_streaming_hashed_open_domain_does_not_raise():
    """More distinct keys than num_buckets: the dense dictionary would
    raise; the hashed key space degrades into shared buckets with the
    collisions reported."""
    events = [(float(i) / 10.0, f"key-{i % 64}", 1.0) for i in range(640)]
    out, report = _run_stream(events, "open", window_size=100.0,
                              num_buckets=16, key_space="hashed",
                              aggregation="count")
    assert report.error is None
    assert report.hash_collisions > 0           # 64 keys into 16 buckets
    total = sum(json.loads(line)[1]
                for blob in out.values() for line in blob.splitlines())
    assert total == len(events)                 # no record lost to hashing


def test_streaming_hashed_matches_dense_when_no_collisions():
    """A hashed stream whose keys happen not to collide produces the same
    per-key aggregates as the dense dictionary run (labels are the real
    keys because each bucket holds one key)."""
    rng = np.random.default_rng(13)
    # probe for a collision-free key set under the 24-bit fold + murmur
    keys, buckets, k = [], set(), 0
    from repro.engine.stages import fold_key24, host_bucket
    while len(keys) < 8:
        name = f"s{k}"
        b = host_bucket(fold_key24(name), 64)
        if b not in buckets:
            buckets.add(b)
            keys.append(name)
        k += 1
    events = [(float(t), keys[int(i)], float(v)) for t, i, v in
              zip(np.sort(rng.uniform(0, 100.0, 800)),
                  rng.integers(0, len(keys), 800),
                  rng.integers(0, 20, 800))]
    dense, rd = _run_stream(events, "dn", window_size=25.0,
                            num_buckets=64, aggregation="sum")
    hashed, rh = _run_stream(events, "hs", window_size=25.0,
                             num_buckets=64, key_space="hashed",
                             aggregation="sum")
    assert rh.hash_collisions == 0
    assert {k: dict(json.loads(ln) for ln in v.splitlines())
            for k, v in dense.items()} == \
           {k: dict(json.loads(ln) for ln in v.splitlines())
            for k, v in hashed.items()}


def test_streaming_hashed_crash_resume_restores_labels():
    """Checkpoint + resume carries the bucket→key label table, so a
    restarted hashed stream emits identical bytes."""
    events = [(float(i) / 4.0, f"key-{i % 40}", 1.0) for i in range(800)]

    built = (Pipeline.from_source(batch_records=100).key_by()
             .window(Windowing.tumbling(50.0)).reduce("count")
             .sink("stream-output/")
             .build(num_buckets=16, n_workers=W, key_space="hashed",
                    batch_records=100, job_id="hres"))

    def make(store, meta):
        return StreamingCoordinator(store, meta, program=built)

    ref_store = MemoryStore()
    make(ref_store, MetadataStore()).run_stream(
        StreamSource.from_records(events, batch_records=100))
    store, meta = MemoryStore(), MetadataStore()
    make(store, meta).run_stream(
        StreamSource.from_records(events[:400], batch_records=100),
        flush=False)
    make(store, meta).run_stream(
        StreamSource.from_records(events, batch_records=100))
    ref = {m.key: ref_store.get(m.key)
           for m in ref_store.list_objects("stream-output/hres/")}
    got = {m.key: store.get(m.key)
           for m in store.list_objects("stream-output/hres/")}
    assert ref and got == ref


# ---------------------------------------------------------------------------
# One plan space: the batch façade and the streaming coordinator agree
# ---------------------------------------------------------------------------

def test_batch_and_streaming_share_the_plan_layer():
    """Folding a stream into a single huge window equals the batch
    aggregate over the same records — one engine, two lowerings."""
    rng = np.random.default_rng(17)
    keys = rng.integers(0, 16, 400).tolist()
    vals = rng.integers(0, 9, 400).tolist()
    batch_plan = ExecutionPlan(KeySpace.dense(16), ReduceSpec("aggregate"),
                               n_workers=W)
    batch, _ = batch_plan.compile(_map_fn).run(_shards(keys, vals))
    stream_plan = ExecutionPlan(
        KeySpace.dense(16), ReduceSpec("aggregate"), n_workers=W,
        window=WindowSpec(size=1e9, n_slots=4))
    compiled = stream_plan.compile()
    carry = compiled.init_carry()
    rows = np.zeros((400, 5), np.float32)
    for i, (k, v) in enumerate(zip(keys, vals)):
        rows[i] = (0, 1, k, v, 1.0)         # every record in window 0
    carry, _ = compiled.step(rows.reshape(W, 100, 5), carry, -(2 ** 31))
    window0 = compiled.read_slot(carry, 0)
    assert np.array_equal(window0[:, 0], np.asarray(batch)[:16])


# ---------------------------------------------------------------------------
# min/max segment kinds under hashed collisions + ring-slot reuse (property)
# ---------------------------------------------------------------------------

def _minmax_oracle(events, kind, *, assigner, num_buckets):
    """Host-numpy oracle: per (window, hash bucket) extremum — colliding
    keys share a bucket, so the group reducer sees their merged value
    list; the emitted label is whichever key the coordinator saw first,
    so comparison is by bucket, not by label."""
    from repro.engine.stages import fold_key24, host_bucket
    per = defaultdict(lambda: defaultdict(list))
    for ts, key, v in events:
        b = host_bucket(fold_key24(key), num_buckets)
        for widx in assigner.assign(ts):
            per[widx][b].append(v)
    red = np.min if kind == "min" else np.max
    return {w: {b: float(red(vs)) for b, vs in bs.items()}
            for w, bs in per.items()}


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.integers(0, 1).map(lambda i: ("min", "max")[i]))
def test_segment_minmax_hashed_collisions_ring_reuse(seed, kind):
    """Property: ``min``/``max`` group reducers are exact under (a) hashed
    key collisions — 40 raw keys folded into 8 buckets, every bucket a
    merged value list — and (b) ring-slot reuse — sliding windows with
    n_slots=4 while the stream spans ~20 window starts, so every slot is
    cleared and refilled several times.  Oracle: host numpy over the same
    bucket assignment."""
    rng = np.random.default_rng(seed)
    n = 1200
    ts = np.sort(rng.uniform(0, 300.0, n))
    keys = rng.integers(0, 40, n)
    vals = rng.integers(-50, 50, n).astype(float)
    events = [(float(t), f"key-{k}", float(v))
              for t, k, v in zip(ts, keys, vals)]
    out, report = _run_stream(events, f"pmm-{kind}-{seed}",
                              window_size=30.0, window_slide=15.0,
                              n_slots=4, mode="group", reduce_fn=kind,
                              capacity=4096, num_buckets=8,
                              key_space="hashed")
    assert report.error is None
    assert report.hash_collisions > 0           # 40 keys into 8 buckets
    assigner = SlidingWindows(30.0, 15.0)
    oracle = _minmax_oracle(events, kind, assigner=assigner, num_buckets=8)
    from repro.engine.stages import fold_key24, host_bucket
    seen = set()
    for blob_key, blob in out.items():
        # "window-{lo:.3f}-{hi:.3f}" — lo may be negative (window -1 spans
        # [-15, 15)), so recover the index from hi, which never is.
        hi = float(blob_key.rsplit("-", 1)[1])
        widx = round((hi - 30.0) / 15.0)
        # Colliding buckets emit "bucket-{b}[k1|k2|...]" labels; a bucket
        # that happened to see one key keeps the raw key label.
        def bucket_of(label):
            if label.startswith("bucket-"):
                return int(label[len("bucket-"):].split("[", 1)[0])
            return host_bucket(fold_key24(label), 8)
        got = {bucket_of(label): value
               for label, value in
               (json.loads(line) for line in blob.splitlines())}
        assert got == pytest.approx(oracle[widx]), (kind, widx)
        seen.add(widx)
    assert seen == set(oracle)                  # every window emitted once
