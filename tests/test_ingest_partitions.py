"""Partitioned shared ingest (``repro.service.ingest_share``).

The claims under test:

* **Determinism across widths** — an N-partition materialization yields
  the same merged record sequence as the single-partition topic (global
  ``seq`` order == physical log order), so partitioning never changes a
  subscriber's bytes.
* **Per-(subscriber, partition) cursors** — a subscriber's scalar
  cursor dissects into per-partition replay cursors that sum to it,
  grow monotonically, and are stable across crash/re-materialization —
  exactly-once per partition.
* **Edge cases from the issue** — late subscriber replaying from 0
  across partitions, partition-skewed traffic (every record one key),
  and crash re-attach with per-partition cursors mid-segment.
* **Partition subsets** — parallel subscribers can split one source by
  partition and together see every record exactly once.
"""

import numpy as np
import pytest

from repro.core import EventBus, MemoryStore, MetadataStore
from repro.pipeline import Pipeline, Windowing
from repro.service import JobServer, JobStatus, ParkPolicy, SharedIngest
from repro.streaming import (StreamSource, StreamingCoordinator,
                             write_event_log)

W = 4


def _events(n=400, n_keys=6, span=100.0, seed=0, t0=0.0):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.uniform(t0, t0 + span, n))
    keys = rng.integers(0, n_keys, n)
    vals = rng.integers(0, 9, n).astype(float)
    return [(float(t), f"k{k}", float(v)) for t, k, v in zip(ts, keys, vals)]


def _program(job_id, *, agg="sum", batch_records=100):
    return (Pipeline.from_source(batch_records=batch_records).key_by()
            .window(Windowing.tumbling(25.0)).reduce(agg)
            .sink("stream-output/")
            .build(num_buckets=16, n_workers=W, batch_records=batch_records,
                   job_id=job_id))


def _standalone(events, job_id, *, agg="sum", batch_records=100):
    built = _program(job_id, agg=agg, batch_records=batch_records)
    store = MemoryStore()
    coord = StreamingCoordinator(store, MetadataStore(), program=built)
    coord.run_stream(
        StreamSource.from_records(events, batch_records=batch_records))
    return {m.key: store.get(m.key)
            for m in store.list_objects(f"stream-output/{job_id}/")}


def _sink_bytes(store, tenant, job_id):
    ns = f"tenants/{tenant}/"
    return {m.key[len(ns):]: store.get(m.key)
            for m in store.list_objects(f"{ns}stream-output/{job_id}/")}


def _ingest(events, n_partitions, *, prefix="part/", seg=64):
    store = MemoryStore()
    write_event_log(store, prefix, events, segment_records=seg)
    ing = SharedIngest(EventBus(), store, prefix, n_partitions=n_partitions)
    ing.pump()
    return ing


# ---------------------------------------------------------------------------
# Merged-view determinism + cursor dissection
# ---------------------------------------------------------------------------

def test_partitioned_merge_equals_single_partition_order():
    events = _events(n=300, seed=1)
    one = _ingest(events, 1)
    four = _ingest(events, 4)
    assert one.end_offset() == four.end_offset() == len(events)
    assert list(four.records_from(0)) == list(one.records_from(0)) == events
    # records actually spread over multiple partitions
    widths = [four.bus.end_offset(four.topic, p) for p in range(4)]
    assert sum(widths) == len(events) and sum(1 for w in widths if w) > 1
    # offsets address the merged view at any position
    for off in (0, 1, 99, 250, len(events)):
        assert list(four.records_from(off)) == events[off:]


def test_partition_cursors_dissect_scalar_cursor_exactly():
    events = _events(n=257, seed=2)
    ing = _ingest(events, 3)
    prev = {p: 0 for p in range(3)}
    for cursor in (0, 1, 64, 200, 257):
        cur = ing.partition_cursors(cursor)
        assert sum(cur.values()) == cursor
        assert all(cur[p] >= prev[p] for p in cur)     # monotone
        prev = cur
    # replaying each partition from its cursor covers exactly the
    # merged-view tail: together the partitions hold each record once
    cursor = 100
    cur = ing.partition_cursors(cursor)
    tail = []
    for p in range(3):
        for rec in ing.bus.fetch(ing.topic, p, cur[p]):
            tail.append(tuple(rec.value.data["record"]))
    assert sorted(tail) == sorted(tuple(e) for e in events[cursor:])


def test_subscriber_partition_subsets_split_the_source():
    events = _events(n=300, seed=3)
    ing = _ingest(events, 4)
    left = ing.subscribe("left", partitions=[0, 1])
    right = ing.subscribe("right", partitions=[2, 3])
    got_left = list(left._events_from(0))
    got_right = list(right._events_from(0))
    assert len(got_left) == left.ingest.end_offset(left.partitions)
    assert sorted(got_left + got_right) == sorted(events)
    assert left.lag(0) + right.lag(0) == len(events)
    # subset views stay in global order too
    seqs = {tuple(e): i for i, e in enumerate(events)}
    assert [seqs[tuple(e)] for e in got_left] == \
        sorted(seqs[tuple(e)] for e in got_left)
    with pytest.raises(ValueError, match="out of range"):
        ing.subscribe("bad", partitions=[7])
    with pytest.raises(ValueError, match="non-empty"):
        ing.subscribe("empty", partitions=[])


# ---------------------------------------------------------------------------
# Issue edge cases, end to end through the JobServer
# ---------------------------------------------------------------------------

def test_late_subscriber_replays_from_zero_across_partitions():
    events = _events(n=400, seed=4)
    store = MemoryStore()
    write_event_log(store, "gps/", events, segment_records=64)
    server = JobServer(store, MetadataStore(), ingest_partitions=3)
    server.add_tenant("alice")
    server.add_tenant("bob")
    server.submit("alice", _program("early-p"), source_prefix="gps/")
    server.step()                       # fully materialized, alice ahead
    assert server.ingests["gps"].n_partitions == 3
    assert server.ingests["gps"].pumped == len(events)
    late = server.submit("bob", _program("late-p", agg="count"),
                         source_prefix="gps/")
    assert server.jobs[late].cursor == 0
    server.run_until_complete()
    assert _sink_bytes(store, "alice", "early-p") == \
        _standalone(events, "early-p")
    assert _sink_bytes(store, "bob", "late-p") == \
        _standalone(events, "late-p", agg="count")


def test_partition_skewed_traffic_single_hot_key():
    """Every record carries one key → every record lands one partition;
    the merged view, cursors, and job bytes must not care."""
    events = [(float(t), "hot", float(v % 9))
              for t, v in zip(np.linspace(0, 100, 300), range(300))]
    ing = _ingest(events, 4)
    widths = [ing.bus.end_offset(ing.topic, p) for p in range(4)]
    assert sorted(widths)[-1] == len(events)        # all on the hot partition
    assert list(ing.records_from(0)) == events
    cur = ing.partition_cursors(123)
    assert sum(cur.values()) == 123 and max(cur.values()) == 123

    store = MemoryStore()
    write_event_log(store, "gps/", events, segment_records=64)
    server = JobServer(store, MetadataStore(), ingest_partitions=4)
    server.add_tenant("alice")
    jid = server.submit("alice", _program("skew-p"), source_prefix="gps/")
    states = server.run_until_complete()
    assert states[jid] == JobStatus.DONE
    assert _sink_bytes(store, "alice", "skew-p") == \
        _standalone(events, "skew-p")


def test_crash_reattach_mid_segment_keeps_partition_cursors():
    """Park at a checkpoint that falls mid-segment (290 records, 64 per
    segment), crash the server, re-materialize on a fresh bus: the
    partition layout and the checkpoint's per-partition cursor dissection
    must come back identical (stable FNV-1a routing + seq merge), and the
    resumed job must finish with standalone byte parity — exactly-once
    per partition across the crash."""
    events = _events(n=400, seed=5)
    first, second = events[:290], events[290:]
    store = MemoryStore()
    meta = MetadataStore()
    write_event_log(store, "gps/", first, segment_records=64)
    server = JobServer(store, meta, ingest_partitions=3,
                       park_policy=ParkPolicy(idle_seconds=0.0))
    server.add_tenant("alice")
    jid = server.submit("alice", _program("crashp-1"), source_prefix="gps/")
    while server.step():
        pass
    assert server.jobs[jid].state == JobStatus.PARKED
    ckpt = server.status(jid)["checkpointed_offset"]
    assert ckpt == 290
    cursors_before = server.jobs[jid].sub.partition_cursors(ckpt)
    del server                          # crash: bus + topics gone with it

    write_event_log(store, "gps/", second, segment_records=64)
    server2 = JobServer(store, meta, ingest_partitions=3)
    server2.add_tenant("alice")
    server2.submit("alice", _program("crashp-1"), source_prefix="gps/",
                   resume=True)
    server2.ingests["gps"].pump()       # re-materialize from the log
    cursors_after = server2.jobs[jid].sub.partition_cursors(ckpt)
    assert cursors_after == cursors_before
    assert sum(cursors_after.values()) == ckpt
    states = server2.run_until_complete()
    assert states[jid] == JobStatus.DONE
    assert _sink_bytes(store, "alice", "crashp-1") == \
        _standalone(events, "crashp-1")
