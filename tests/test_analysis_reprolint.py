"""reprolint: golden diagnostics per rule over inline sources (one
trigger + one clean each), suppression + allowlist mechanics, the lane
decorator contract, and the sweep regression — the shipped tree lints
clean, which is what keeps the CI job blocking."""

import pathlib
import textwrap

import pytest

from repro.analysis.lanes import LANES, lane
from repro.analysis.lint import main as lint_main
from repro.analysis.reprolint import (lint_paths, lint_source,
                                      load_allowlist)

REPO = pathlib.Path(__file__).resolve().parent.parent


def _lint(src, path="src/repro/streaming/foo.py"):
    return lint_source(textwrap.dedent(src), path)


def _rules(findings):
    return [d.rule_id for d in findings]


# ---------------------------------------------------------------------------
# RL101 — shard_map confinement
# ---------------------------------------------------------------------------

def test_rl101_import_forms():
    (d,) = _lint("import jax.experimental.shard_map as shmap\n")
    assert d.rule_id == "RL101" and d.line == 1
    assert "make_shard_map" in d.message
    (d,) = _lint("from jax.experimental.shard_map import shard_map\n")
    assert d.rule_id == "RL101"
    (d,) = _lint("from jax.experimental import shard_map\n")
    assert d.rule_id == "RL101"
    (d,) = _lint("import jax\n\ndef f(g):\n    return jax.shard_map(g)\n")
    assert d.rule_id == "RL101" and d.line == 4


def test_rl101_allowed_in_compile_and_for_plain_jax():
    src = "from jax.experimental.shard_map import shard_map\n"
    assert lint_source(src, "src/repro/engine/compile.py") == []
    assert _lint("import jax\nimport jax.numpy as jnp\n") == []
    # the dispatch *string* is not a reference
    assert _lint("backend = 'shard_map'\n") == []


# ---------------------------------------------------------------------------
# RL102 — host syncs in hot lanes
# ---------------------------------------------------------------------------

_LANE_MODULE = """\
import numpy as np
from repro.analysis.lanes import lane

LANE_DEVICE_STATE = {{"carry", "stats"}}


class C:
    @lane("{lane}")
    def f(self, stats, rows):
        {body}
"""


def _lane_lint(body, lane_name="driver"):
    return _lint(_LANE_MODULE.format(lane=lane_name, body=body))


def test_rl102_sync_calls_in_driver():
    for body, what in [
            ("return np.asarray(stats)", "np.asarray"),
            ("return rows.block_until_ready()", "block_until_ready"),
            ("return rows.item()", ".item()"),
            ("import jax; return jax.device_get(rows)", "jax.device_get"),
            ("return int(stats[0])", "int() over device state"),
    ]:
        (d,) = _lane_lint(body)
        assert d.rule_id == "RL102", body
        assert what in d.message and "barrier" in d.message


def test_rl102_barrier_lane_and_benign_calls_clean():
    assert _lane_lint("return np.asarray(stats)", "barrier") == []
    # host→device transfer is not a sync; ints over local names are fine
    assert _lane_lint("import jax.numpy as jnp; "
                      "return jnp.asarray(rows)") == []
    assert _lane_lint("n = len(rows); return int(n)") == []
    # unannotated functions are unrestricted
    assert _lint("import numpy as np\n\ndef f(x):\n"
                 "    return np.asarray(x)\n") == []


def test_rl102_nested_def_inherits_lane():
    src = """\
    import numpy as np
    from repro.analysis.lanes import lane

    @lane("driver")
    def outer(stats):
        def inner():
            return np.asarray(stats)
        return inner
    """
    (d,) = _lint(src)
    assert d.rule_id == "RL102"


# ---------------------------------------------------------------------------
# RL103 — shared-state lane table
# ---------------------------------------------------------------------------

_SHARED_MODULE = """\
from repro.analysis.lanes import lane

LANE_SHARED = {{"_pending_stats": ("driver", "barrier"),
               "tables": ("driver",)}}


class C:
    @lane("{lane}")
    def f(self, x):
        {body}
"""


def _shared_lint(body, lane_name="prefetch"):
    return _lint(_SHARED_MODULE.format(lane=lane_name, body=body))


def test_rl103_mutations_off_lane():
    (d,) = _shared_lint("self._pending_stats.append(x)")
    assert d.rule_id == "RL103"
    assert "._pending_stats" in d.message and "'prefetch'" in d.message
    (d,) = _shared_lint("self._pending_stats = []")
    assert d.rule_id == "RL103"
    (d,) = _shared_lint("self._pending_stats += [x]")
    assert d.rule_id == "RL103"
    (d,) = _shared_lint("self.tables[0].load_state_dict(x)", "barrier")
    assert d.rule_id == "RL103" and "('driver',)" in d.message


def test_rl103_declared_lanes_and_unannotated_clean():
    assert _shared_lint("self._pending_stats.append(x)", "driver") == []
    assert _shared_lint("self._pending_stats.append(x)", "barrier") == []
    assert _shared_lint("self.other_state = x") == []       # undeclared attr
    assert _lint("""\
    LANE_SHARED = {"_pending_stats": ("driver",)}

    class C:
        def f(self, x):                  # no @lane: unrestricted
            self._pending_stats.append(x)
    """) == []


# ---------------------------------------------------------------------------
# RL104 — SPMD body purity
# ---------------------------------------------------------------------------

_KPATH = "src/repro/kernels/foo.py"


def test_rl104_impure_constructs():
    findings = lint_source(textwrap.dedent("""\
    import numpy as np
    _n = 0

    def body(x):
        global _n
        print(x)
        if x.any():
            return np.asarray(x)
        return x
    """), _KPATH)
    assert sorted(_rules(findings)) == ["RL104", "RL104", "RL104", "RL104"]
    msgs = " | ".join(d.message for d in findings)
    assert "global" in msgs and "print()" in msgs
    assert "traced reduction" in msgs and "np.asarray" in msgs


def test_rl104_static_branches_and_other_paths_clean():
    clean = """\
    import jax.numpy as jnp

    def body(x, hashed):
        if hashed:                      # static python bool: fine
            return jnp.sum(x)
        while x.shape[0] > 1:           # shape is static under trace
            x = x[:1]
        return x
    """
    assert lint_source(textwrap.dedent(clean), _KPATH) == []
    # the same impure code outside stages/kernels is not RL104's business
    assert _lint("def f(x):\n    print(x)\n") == []


def test_rl104_applies_to_engine_stages():
    (d,) = lint_source("def f(x):\n    print(x)\n",
                       "src/repro/engine/stages.py")
    assert d.rule_id == "RL104"


# ---------------------------------------------------------------------------
# RL105 — donated buffer rebinding
# ---------------------------------------------------------------------------

def test_rl105_unrebound_donation():
    (d,) = _lint("def f(step, c):\n    step(c, donate=True)\n")
    assert d.rule_id == "RL105" and "stale buffer" in d.message
    (d,) = _lint("def f(step, c):\n    out = step(c, donate=True)\n")
    assert d.rule_id == "RL105"                  # result != donated arg


def test_rl105_rebound_and_disabled_donation_clean():
    assert _lint("def f(step, c):\n"
                 "    c, stats = step(rows, c, donate=True)\n") == []
    assert _lint("def f(self, step, st):\n"
                 "    st.carry, _ = step(st.carry, "
                 "donate=self.opts.donate_carry)\n") == []
    assert _lint("def f(step, c):\n    step(c, donate=False)\n") == []
    assert _lint("def f(step, c):\n    step(c, donate=None)\n") == []


# ---------------------------------------------------------------------------
# RL106 — exported names carry docstrings
# ---------------------------------------------------------------------------

def test_rl106_undocumented_export():
    (d,) = _lint('__all__ = ["f"]\n\ndef f():\n    return 1\n')
    assert d.rule_id == "RL106" and d.line == 3
    assert "'f'" in d.message and "docstring" in d.message
    (d,) = _lint('__all__ = ["C"]\n\nclass C:\n    x = 1\n')
    assert d.rule_id == "RL106" and d.line == 3


def test_rl106_documented_private_and_reexported_clean():
    assert _lint('__all__ = ["f"]\n\ndef f():\n    "Docs."\n    return 1\n'
                 ) == []
    # names not exported need no docstring
    assert _lint('__all__ = ["f"]\n\ndef f():\n    "Docs."\n\ndef _g():\n'
                 '    return 2\n') == []
    # re-exports are someone else's definition — checked at home
    assert _lint('from os.path import join\n__all__ = ["join"]\n') == []
    # no __all__ at all: module opted out of the public-surface contract
    assert _lint('def f():\n    return 1\n') == []


# ---------------------------------------------------------------------------
# suppressions + allowlist
# ---------------------------------------------------------------------------

def test_line_suppression_scopes_to_rule_and_line():
    base = "import jax.experimental.shard_map as s{}\n"
    assert _lint(base.format("  # reprolint: disable=RL101")) == []
    assert _lint(base.format("  # reprolint: disable")) == []
    (d,) = _lint(base.format("  # reprolint: disable=RL102"))
    assert d.rule_id == "RL101"                  # wrong id: still reported
    (d,) = _lint("# reprolint: disable=RL101\n" + base.format(""))
    assert d.rule_id == "RL101"                  # wrong line: still reported


def test_file_suppression():
    src = ("# reprolint: disable-file=RL101\n"
           "import jax.experimental.shard_map as s\n")
    assert _lint(src) == []


def test_allowlist_globs(tmp_path):
    bad = tmp_path / "legacy" / "old.py"
    bad.parent.mkdir()
    bad.write_text("import jax.experimental.shard_map as s\n")
    assert _rules(lint_paths([tmp_path])) == ["RL101"]
    allow = tmp_path / ".reprolint-allow"
    allow.write_text("# reviewed exception\n*legacy/*::RL101\n")
    assert lint_paths([tmp_path], load_allowlist(allow)) == []
    allow.write_text("*legacy/*::RL105\n")       # wrong rule: still blocks
    assert _rules(lint_paths([tmp_path], load_allowlist(allow))) == ["RL101"]
    allow.write_text("*legacy/*::*\n")           # rule wildcard
    assert lint_paths([tmp_path], load_allowlist(allow)) == []


def test_cli_exit_codes(tmp_path, capsys):
    assert lint_main(["--list-rules"]) == 0
    assert "RL101" in capsys.readouterr().out
    good = tmp_path / "ok.py"
    good.write_text("x = 1\n")
    assert lint_main([str(good)]) == 0
    bad = tmp_path / "bad.py"
    bad.write_text("import jax.experimental.shard_map as s\n")
    assert lint_main([str(bad)]) == 1
    assert "RL101" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# lane decorator + sweep regression
# ---------------------------------------------------------------------------

def test_lane_decorator_contract():
    @lane("driver")
    def f():
        pass

    assert f.__lane__ == "driver" and set(LANES) >= {"driver"}
    with pytest.raises(ValueError, match="unknown lane"):
        lane("turbo")


def test_coordinator_is_lane_annotated():
    from repro.streaming.coordinator import (LANE_SHARED,
                                             StreamingCoordinator)
    assert LANE_SHARED["_pending_stats"] == ("driver", "barrier")
    assert StreamingCoordinator._prepare_batch.__lane__ == "prefetch"
    assert StreamingCoordinator._fold_device.__lane__ == "driver"
    assert StreamingCoordinator.save_state.__lane__ == "barrier"


def test_shipped_tree_lints_clean():
    allow = load_allowlist(REPO / ".reprolint-allow")
    findings = lint_paths([REPO / "src", REPO / "tests",
                           REPO / "benchmarks", REPO / "examples"], allow)
    assert findings == [], "\n".join(d.format() for d in findings)
