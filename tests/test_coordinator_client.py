"""Coordinator reliability (retries, speculation, restart) and the client
package (Fig. 4: async multi-job, chained map stages)."""

import time
from collections import Counter


from repro.core import (Coordinator, Job, JobState, MapReduce, MemoryStore,
                        MetadataStore, make_wordcount_job, read_final_output)
from repro.core.job import JobConfig
from repro.data.pipeline import synth_corpus

CORPUS = synth_corpus(15_000, vocab_words=100, seed=1)
EXPECTED = dict(Counter(CORPUS.split()))


def _stack():
    store = MemoryStore()
    store.put("input/corpus.txt", CORPUS.encode())
    return store, MetadataStore()


def test_retry_on_transient_mapper_failure():
    store, meta = _stack()
    failures = {("mapper", 1, 0), ("mapper", 2, 0)}   # fail first attempts

    def inject(role, wid, attempt):
        if (role, wid, attempt) in failures:
            failures.discard((role, wid, attempt))
            raise RuntimeError("simulated container crash")

    coord = Coordinator(store, meta, fault_injector=inject,
                        max_task_retries=2)
    cfg = make_wordcount_job(n_mappers=4, n_reducers=2)
    report = coord.run_job(cfg)
    assert report.state == JobState.DONE
    assert report.retries == 2
    assert read_final_output(cfg, store) == EXPECTED


def test_job_fails_after_retry_budget():
    store, meta = _stack()

    def always_fail(role, wid, attempt):
        if role == "reducer" and wid == 0:
            raise RuntimeError("permanent failure")

    coord = Coordinator(store, meta, fault_injector=always_fail,
                        max_task_retries=1)
    cfg = make_wordcount_job(n_mappers=2, n_reducers=2)
    report = coord.run_job(cfg)
    assert report.state == JobState.FAILED
    assert "permanent failure" in (report.error or "") or report.error


def test_speculative_execution_on_straggler():
    store, meta = _stack()
    slow_once = {0}

    def inject(role, wid, attempt):
        if role == "mapper" and wid in slow_once:
            slow_once.discard(wid)
            time.sleep(1.2)        # straggle far beyond the median

    coord = Coordinator(store, meta, fault_injector=inject,
                        straggler_factor=3.0, straggler_min_seconds=0.2,
                        speculative_execution=True)
    cfg = make_wordcount_job(n_mappers=4, n_reducers=2)
    report = coord.run_job(cfg)
    assert report.state == JobState.DONE
    assert report.speculative_launches >= 1
    assert read_final_output(cfg, store) == EXPECTED


def test_coordinator_restart_resumes_job(tmp_path):
    """Stateless coordinator: a new instance resumes from metadata."""
    store, _ = _stack()
    meta = MetadataStore(persist_path=str(tmp_path / "meta.json"))
    coord = Coordinator(store, meta)
    cfg = make_wordcount_job(n_mappers=3, n_reducers=2)
    # simulate a crash mid-MAPPING by setting state then abandoning
    coord.meta.set(f"job:{cfg.job_id}:config", cfg.to_json())
    coord._set_state(cfg.job_id, JobState.MAPPING)

    meta2 = MetadataStore(persist_path=str(tmp_path / "meta.json"))
    coord2 = Coordinator(store, meta2)
    report = coord2.resume_job(cfg.job_id)
    assert report.state == JobState.DONE
    assert read_final_output(cfg, store) == EXPECTED


# -- client package (Fig. 4) ---------------------------------------------------

def upper_mapper(key, chunk):
    for word in chunk.split():
        yield word.upper(), 1


def count_mapper(key, chunk):
    import json
    for line in chunk.splitlines():
        if line.strip():
            k, v = json.loads(line)
            yield k, v


def sum_reducer(key, values):
    return key, sum(values)


def test_client_single_job():
    store, meta = _stack()
    coord = Coordinator(store, meta)
    job = Job(payload=JobConfig(n_mappers=2, n_reducers=2),
              mappers=[upper_mapper], reducer=sum_reducer)
    mr = MapReduce(coord, [job])
    ids = mr.run_sync()
    assert len(ids) == 1 and len(ids[0]) == 1
    out = read_final_output(job.build_stages()[-1], store)
    assert out == {k.upper(): v for k, v in EXPECTED.items()}


def test_client_chained_map_stages():
    """Two map functions + reducer = two chained jobs (paper §III-D)."""
    store, meta = _stack()
    coord = Coordinator(store, meta)
    job = Job(payload=JobConfig(n_mappers=2, n_reducers=2),
              mappers=[upper_mapper, count_mapper], reducer=sum_reducer)
    stages = job.build_stages()
    assert len(stages) == 2
    assert stages[0].n_reducers == 0          # map-only first stage
    mr = MapReduce(coord, [Job(payload=JobConfig(n_mappers=2, n_reducers=2),
                               mappers=[upper_mapper, count_mapper],
                               reducer=sum_reducer)])
    ids = mr.run_sync()
    assert len(ids[0]) == 2


def test_client_parallel_jobs():
    store, meta = _stack()
    coord = Coordinator(store, meta)
    jobs = [Job(payload=JobConfig(n_mappers=2, n_reducers=1),
                mappers=[upper_mapper], reducer=sum_reducer)
            for _ in range(3)]
    mr = MapReduce(coord, jobs)
    ids = mr.run_sync()
    assert len(ids) == 3
    for job in jobs:
        out = read_final_output(job.build_stages()[-1], store)
        assert out == {k.upper(): v for k, v in EXPECTED.items()}
