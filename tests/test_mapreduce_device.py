"""Device-plane MapReduce: shuffle invariants (hypothesis) + engine modes."""

import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic container: seeded-sampling shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.mapreduce import segment_reduce
from repro.core.shuffle import (build_send_buffers, hash_partition,
                                local_combine_dense, sort_and_group)
from repro.pipeline import Pipeline

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

keys_vals = st.integers(2, 64).flatmap(
    lambda n: st.tuples(
        st.lists(st.integers(0, 31), min_size=n, max_size=n),
        st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                 min_size=n, max_size=n)))


@given(keys_vals)
def test_local_combine_matches_oracle(kv):
    ks, vs = kv
    keys = jnp.asarray(ks, jnp.int32)
    vals = jnp.asarray(vs, jnp.float32)
    got = np.asarray(local_combine_dense(keys, vals, 32))
    want = np.zeros(32, np.float32)
    for k, v in zip(ks, vs):
        want[k] += np.float32(v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(keys_vals, st.integers(1, 8))
def test_send_buffers_partition_and_conserve(kv, n_part):
    """Spill-buffer packing: every kept record lands in hash(key) % R's
    buffer; records are only lost to capacity overflow, and the overflow
    count is exact."""
    ks, vs = kv
    keys = jnp.asarray(ks, jnp.int32)
    vals = jnp.asarray(vs, jnp.float32)
    cap = 8
    sk, sv, svalid, stats = build_send_buffers(keys, vals, n_part, cap)
    sk, sv, svalid = map(np.asarray, (sk, sv, svalid))
    dests = np.asarray(hash_partition(keys, n_part))
    kept = int(svalid.sum())
    assert kept + int(stats.dropped) == len(ks)
    for p in range(n_part):
        got = sorted(sk[p][svalid[p]].tolist())
        want = sorted(np.asarray(ks)[dests == p].tolist())[:None]
        # kept records must be a sub-multiset of the records routed to p
        for g in got:
            assert g in want
            want.remove(g)
        assert len(got) == min((dests == p).sum(), cap)


@given(keys_vals)
def test_sort_and_group_marks_groups(kv):
    ks, vs = kv
    keys = jnp.asarray(ks, jnp.int32)
    vals = jnp.asarray(vs, jnp.float32)
    sk, sv, starts = sort_and_group(keys, vals)
    sk, starts = np.asarray(sk), np.asarray(starts)
    assert (np.diff(sk) >= 0).all()
    n_groups = int(starts.sum())
    assert n_groups == len(set(ks))


def test_segment_reduce_kinds():
    keys = jnp.asarray([1, 1, 2, 5, 5, 5], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0, 6.0], jnp.float32)
    sk, sv, starts = sort_and_group(keys, vals)
    for kind, expect in [("sum", {1: 3.0, 2: 3.0, 5: 15.0}),
                         ("max", {1: 2.0, 2: 3.0, 5: 6.0}),
                         ("min", {1: 1.0, 2: 3.0, 5: 4.0}),
                         ("mean", {1: 1.5, 2: 3.0, 5: 5.0})]:
        gk, gv, gvalid = segment_reduce(kind, sk, sv, starts)
        got = {int(k): float(v) for k, v, ok in
               zip(np.asarray(gk), np.asarray(gv), np.asarray(gvalid)) if ok}
        assert got == expect, kind


def _make_shards(n_workers, n_per, n_keys, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n_keys, (n_workers, n_per), dtype=np.int32)
    vals = rng.integers(1, 5, (n_workers, n_per), dtype=np.int32)
    return np.stack([keys, vals], axis=-1)


def _array_job(shard, *, num_buckets, n_workers, mode=None, capacity=0,
               combine_fn=None,
               map_fn=lambda s: (s[:, 0], s[:, 1].astype(jnp.float32),
                                 jnp.ones(s.shape[0], bool))):
    spec = "sum"
    built = (Pipeline.from_source(shards=shard).map(map_fn)
             .reduce(spec, mode=mode or "aggregate", capacity=capacity)
             .build(num_buckets=num_buckets, n_workers=n_workers,
                    backend="vmap", combine_fn=combine_fn))
    return built.run_batch(data=shard)


def test_aggregate_vs_group_modes_agree():
    W, n_keys = 4, 32
    shard = _make_shards(W, 500, n_keys, 3)
    agg, _ = _array_job(shard, num_buckets=n_keys, n_workers=W)
    agg = np.asarray(agg)
    (gk, gv, gvalid), gstats = _array_job(shard, num_buckets=n_keys,
                                          n_workers=W, mode="group",
                                          capacity=4096)
    assert int(np.sum(np.asarray(gstats.dropped))) == 0
    got = {int(k): float(v) for k, v, ok in
           zip(np.asarray(gk), np.asarray(gv), np.asarray(gvalid)) if ok}
    for k in range(n_keys):
        assert got.get(k, 0.0) == agg[k]


def test_group_mode_capacity_drops_are_reported():
    W = 2
    shard = _make_shards(W, 512, 4, 0)
    _, stats = _array_job(shard, num_buckets=4, n_workers=W, mode="group",
                          capacity=16)
    assert int(np.sum(np.asarray(stats.dropped))) > 0


def test_pallas_combiner_in_engine():
    """The hash_combine kernel slots into the aggregating shuffle."""
    from repro.kernels.hash_combine.ops import make_combine_fn
    W, n_keys = 4, 64
    shard = _make_shards(W, 256, n_keys, 5)
    ref, _ = _array_job(shard, num_buckets=n_keys, n_workers=W)
    got, _ = _array_job(shard, num_buckets=n_keys, n_workers=W,
                        combine_fn=make_combine_fn(use_pallas=True))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5)
