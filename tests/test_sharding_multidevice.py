"""Sharding planner rules (on the abstract production mesh) and true
multi-device SPMD semantics (8 host devices in a subprocess)."""

import os
import subprocess
import sys

import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro import configs
from repro.launch.shardings import Planner
from repro.models import init_params
from repro.optim import AdamW
from repro.runtime.train_step import init_train_state

try:
    # jax >= 0.5: AbstractMesh(axis_sizes, axis_names)
    MESH = AbstractMesh((16, 16), ("data", "model"))
except TypeError:
    # jax 0.4.x: AbstractMesh(((name, size), ...)) pair form
    MESH = AbstractMesh((("data", 16), ("model", 16)))


def _specs(arch):
    cfg = configs.get(arch)
    planner = Planner(MESH, cfg)
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.random.PRNGKey(0))
    return cfg, planner.param_specs(shapes), shapes


def test_dense_param_rules():
    cfg, specs, shapes = _specs("yi-34b")
    assert specs["embed"] == P("model", "data")        # vocab 64000 % 16 == 0
    assert specs["lm_head"] == P("data", "model")
    lay = specs["layers"]
    assert lay["attn"]["wq"] == P(None, "data", "model")
    assert lay["attn"]["wo"] == P(None, "model", "data")
    assert lay["ffn"]["w_down"] == P(None, "model", "data")
    assert lay["norm1"]["w"] == P(None, None)          # replicated


def test_nondivisible_vocab_falls_back():
    cfg, specs, _ = _specs("internvl2-2b")             # vocab 92553 odd
    assert specs["embed"] == P(None, "data")


def test_moe_expert_rules():
    cfg, specs, _ = _specs("mixtral-8x7b")
    lay = specs["layers"]
    # 8 experts don't divide the 16-way model axis → TP inside experts
    assert lay["ffn"]["w_gate"] == P(None, None, "data", "model")
    assert lay["ffn"]["w_down"] == P(None, None, "model", "data")


def test_optimizer_state_mirrors_params():
    cfg = configs.get("qwen3-32b")
    planner = Planner(MESH, cfg)
    state_shape = jax.eval_shape(
        lambda k: init_train_state(k, cfg, AdamW()), jax.random.PRNGKey(0))
    specs = planner.state_specs(state_shape)
    assert specs.params["embed"] == specs.opt_state.m["embed"]
    assert specs.params["layers"]["ffn"]["w_up"] == \
        specs.opt_state.v["layers"]["ffn"]["w_up"]
    assert specs.step == P()


def test_cache_specs_decode_and_long():
    cfg = configs.get("yi-34b")
    planner = Planner(MESH, cfg)
    from repro.models import init_cache
    cache = jax.eval_shape(lambda: init_cache(cfg, 128, 32768))
    specs = planner.cache_specs(cache, 128)
    assert specs["k"] == P(None, ("data",), None, "model", None) or \
        specs["k"] == P(None, ("data",), None, "model", None)
    # long_500k: batch=1 → sequence sharded over both axes
    cache1 = jax.eval_shape(lambda: init_cache(cfg, 1, 524288))
    specs1 = planner.cache_specs(cache1, 1)
    assert specs1["k"][3] == ("data", "model")


MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp, json
from collections import Counter
from repro.core.mapreduce import wordcount_map_factory
from repro.pipeline import Pipeline

rng = np.random.default_rng(0)
W, n_keys, n_per = 8, 64, 512
keys = rng.integers(0, n_keys, (W, n_per)).astype(np.int32)
vals = np.ones_like(keys)
shard = np.stack([keys, vals], -1).reshape(W * n_per, 2)

mesh = jax.make_mesh((8,), ("workers",))
map_fn = wordcount_map_factory(n_keys)
agg = (Pipeline.from_source(shards=shard).map(map_fn).reduce("sum")
       .build(num_buckets=n_keys, n_workers=8, backend="shard_map",
              mesh=mesh))
res, _stats = agg.run_batch(data=shard)
res = np.asarray(res)
want = np.zeros(n_keys)
for k in keys.ravel():
    want[k] += 1
assert np.allclose(res, want), "aggregate mismatch"

grp = (Pipeline.from_source(shards=shard).map(map_fn)
       .reduce("sum", mode="group", capacity=2048)
       .build(num_buckets=n_keys, n_workers=8, backend="shard_map",
              mesh=mesh))
(gk, gv, gvalid), _gstats = grp.run_batch(data=shard)
got = {int(k): float(v) for k, v, ok in
       zip(np.asarray(gk), np.asarray(gv), np.asarray(gvalid)) if ok}
assert got == {i: float(want[i]) for i in range(n_keys) if want[i] > 0}
print("MULTIDEVICE_OK")
"""


def test_shard_map_backend_on_8_devices():
    """Real SPMD (not vmap simulation): 8 host devices in a subprocess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "MULTIDEVICE_OK" in out.stdout, out.stderr[-2000:]
