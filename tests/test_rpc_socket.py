"""Frame RPC transport (``repro.core.rpc``) and the socket control plane.

Unit tests pin the wire contract — length-prefixed JSON frames, the
oversize cap, error replies instead of torn connections, dispatch
serialization under the server lock, and bounded client retries.  The
end-to-end test then runs a real :class:`JobSocketServer` in a *child
process* and drives submit/pause/resume/status/drain through a
``JobServiceClient(address=...)`` from the parent — the issue's
acceptance criterion that control-plane verbs round-trip across a
process boundary.
"""

import multiprocessing as mp
import socket
import threading
import time

import pytest

from repro.core.rpc import (MAX_FRAME_BYTES, FrameClient, FrameServer,
                            RPCError, recv_frame, send_frame)

# ---------------------------------------------------------------------------
# Wire-format units
# ---------------------------------------------------------------------------


def test_send_recv_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    try:
        msg = {"method": "status", "job_id": "j1", "n": [1, 2, 3]}
        send_frame(a, msg)
        assert recv_frame(b) == msg
        # several frames back to back stay framed
        for i in range(3):
            send_frame(a, {"i": i})
        assert [recv_frame(b)["i"] for _ in range(3)] == [0, 1, 2]
    finally:
        a.close()
        b.close()


def test_recv_frame_returns_none_on_clean_eof_and_raises_mid_frame():
    a, b = socket.socketpair()
    a.close()
    assert recv_frame(b) is None          # EOF between frames: orderly
    b.close()

    a, b = socket.socketpair()
    try:
        a.sendall(b"\x00\x00")            # half a length header
        a.close()
        with pytest.raises(ConnectionError, match="mid-frame"):
            recv_frame(b)
    finally:
        b.close()


def test_oversize_frames_rejected_both_directions():
    a, b = socket.socketpair()
    try:
        with pytest.raises(ValueError, match="exceeds"):
            send_frame(a, "x" * MAX_FRAME_BYTES)   # + quotes > cap
        # a corrupt header claiming gigabytes must not allocate them
        import struct
        a.sendall(struct.pack(">I", MAX_FRAME_BYTES + 1))
        with pytest.raises(RPCError, match="MAX_FRAME_BYTES"):
            recv_frame(b)
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# FrameServer / FrameClient
# ---------------------------------------------------------------------------


def test_frame_server_echo_roundtrip():
    with FrameServer(lambda req: {"ok": True, "echo": req}) as srv:
        with FrameClient(srv.address) as client:
            assert client.call({"x": 1}) == {"ok": True, "echo": {"x": 1}}
            # the connection persists across calls
            for i in range(5):
                assert client.call({"i": i})["echo"]["i"] == i


def test_handler_errors_become_error_replies_not_disconnects():
    def handle(req):
        if req.get("boom"):
            raise ValueError("kaput")
        return {"ok": True, "obj": object()}    # not JSON-serializable

    with FrameServer(handle) as srv, FrameClient(srv.address) as client:
        resp = client.call({"boom": True})
        assert resp["ok"] is False and "ValueError: kaput" in resp["error"]
        resp = client.call({})
        assert resp["ok"] is False and "TypeError" in resp["error"]
        # and the connection survived both
        assert client.call({"boom": True})["ok"] is False


def test_concurrent_clients_serialize_through_the_dispatch_lock():
    state = {"n": 0}

    def handle(req):
        seen = state["n"]
        time.sleep(0.002)                 # widen any race window
        state["n"] = seen + 1
        return {"ok": True, "n": state["n"]}

    with FrameServer(handle) as srv:
        def worker():
            with FrameClient(srv.address) as c:
                for _ in range(10):
                    c.call({})

        threads = [threading.Thread(target=worker) for _ in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert state["n"] == 30               # lost updates ⇒ lock is broken


def test_client_exhausts_retries_then_raises_rpcerror():
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()                         # nobody listening here now
    client = FrameClient(("127.0.0.1", port), timeout=0.2, retries=1,
                         retry_delay=0.01)
    with pytest.raises(RPCError, match="after 2 attempt"):
        client.call({"method": "status"})


# ---------------------------------------------------------------------------
# End to end: control plane across a real process boundary
# ---------------------------------------------------------------------------


def _serve_job_service(conn):
    """Child process: stand up a JobServer behind a JobSocketServer,
    report the bound address, serve until the parent says done."""
    from repro.core import MemoryStore, MetadataStore
    from repro.launch.serve import JobRPC, JobSocketServer
    from repro.pipeline import Pipeline, Windowing
    from repro.service import JobServer
    from repro.streaming import write_event_log

    events = [(float(i) * 0.5, f"k{i % 4}", float(i % 7)) for i in range(200)]
    store = MemoryStore()
    write_event_log(store, "gps/", events, segment_records=64)
    server = JobServer(store, MetadataStore())
    server.add_tenant("alice")
    program = (Pipeline.from_source(batch_records=50).key_by()
               .window(Windowing.tumbling(25.0)).reduce("sum")
               .sink("stream-output/")
               .build(num_buckets=16, n_workers=4, batch_records=50,
                      job_id="rollup-1"))
    rpc = JobRPC(server)
    rpc.register("rollup", program)
    with JobSocketServer(rpc) as srv:
        conn.send(list(srv.address))
        conn.recv()                       # block until the parent is done
    conn.close()


def test_control_plane_verbs_round_trip_between_processes():
    from repro.core import JobServiceClient

    ctx = mp.get_context("spawn")         # fresh interpreter: no inherited
    parent_conn, child_conn = ctx.Pipe()  # JAX/thread state from pytest
    proc = ctx.Process(target=_serve_job_service, args=(child_conn,),
                       daemon=True)
    proc.start()
    try:
        assert parent_conn.poll(120), "server child never came up"
        address = tuple(parent_conn.recv())
        client = JobServiceClient(address=address, timeout=30.0)
        try:
            jid = client.submit("alice", "rollup", source_prefix="gps/")
            assert client.status(jid)["state"] == "PENDING"

            client.pause(jid)
            assert client.status(jid)["state"] == "PAUSED"
            client.resume(jid)
            assert client.status(jid)["state"] != "PAUSED"

            states = client.drain(timeout=120.0)
            assert states[jid] == "DONE"
            st = client.status(jid)
            assert st["state"] == "DONE"
            assert st["windows_emitted"] > 0
            assert st["checkpointed_offset"] == 200 and st["lag"] == 0
            assert st["fold_invocations"] > 0 and st["pool_seconds"] > 0
            assert jid in client.jobs()

            # server-side exceptions surface as RPCError with the cause
            with pytest.raises(RPCError, match="KeyError"):
                client.status("no-such-job")
            # unknown program name likewise
            with pytest.raises(RPCError, match="no program registered"):
                client.submit("alice", "ghost", source_prefix="gps/")
        finally:
            client.close()
        parent_conn.send("done")
        proc.join(timeout=30)
        assert proc.exitcode == 0
    finally:
        if proc.is_alive():
            proc.terminate()
            proc.join(timeout=10)
