"""The multi-tenant job service (``repro.service``).

The platform claims under test, each asserted against ground truth:

* **Physical-once ingest** — N tenants on one source read every log
  segment exactly once (a counting store proves it), yet each tenant's
  sink is byte-identical to a standalone single-pipeline run.
* **Scale-to-zero round trip** — an idle job parks (pool at zero
  replicas), the next matching event cold-restores it (latency
  recorded), and the final bytes are still exactly-once.
* **Crash re-attach** — a fresh ``JobServer`` over the same store+meta
  resumes a checkpointed job with ``resume=True`` and finishes with
  byte parity.
* **Late registration** — a job submitted after the ingest has already
  materialized replays from cursor 0 and catches up.
* **Tenancy** — quota breaches fail only the offending job; cross-job
  sink-prefix collisions are rejected at submit.
* **Control plane** — pause/resume/cancel/status through the
  ``JobRPC`` skeleton and the metadata-only ``JobServiceClient``.
"""

from collections import Counter

import numpy as np
import pytest

from repro.core import (JobServiceClient, MemoryStore, MetadataStore,
                        QuotaExceeded)
from repro.launch.serve import JobRPC
from repro.pipeline import Pipeline, PipelineError, Windowing
from repro.service import JobServer, JobStatus, ParkPolicy
from repro.streaming import (StreamSource, StreamingCoordinator,
                             write_event_log)

W = 4


class CountingStore(MemoryStore):
    """MemoryStore that counts get() calls per key — the analogue of the
    paper's per-request S3 billing line."""

    def __init__(self):
        super().__init__()
        self.gets = Counter()

    def get(self, key):
        self.gets[key] += 1
        return super().get(key)


def _events(n=600, n_keys=5, span=120.0, seed=0):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.uniform(0, span, n))       # in-order: no late drops
    keys = rng.integers(0, n_keys, n)
    vals = rng.integers(0, 9, n).astype(float)  # ints exact in fp32
    return [(float(t), f"k{k}", float(v)) for t, k, v in zip(ts, keys, vals)]


def _program(job_id, *, agg="sum", batch_records=100):
    return (Pipeline.from_source(batch_records=batch_records).key_by()
            .window(Windowing.tumbling(25.0)).reduce(agg)
            .sink("stream-output/")
            .build(num_buckets=16, n_workers=W, batch_records=batch_records,
                   job_id=job_id))


def _standalone(events, job_id, *, agg="sum", batch_records=100):
    """Ground truth: the same program driven alone on a private store."""
    built = _program(job_id, agg=agg, batch_records=batch_records)
    store = MemoryStore()
    coord = StreamingCoordinator(store, MetadataStore(), program=built)
    coord.run_stream(
        StreamSource.from_records(events, batch_records=batch_records))
    return {m.key: store.get(m.key)
            for m in store.list_objects(f"stream-output/{job_id}/")}


def _sink_bytes(store, tenant, job_id):
    """A tenant's sink on the shared store, keyed namespace-relative so it
    compares directly against a standalone run."""
    ns = f"tenants/{tenant}/"
    return {m.key[len(ns):]: store.get(m.key)
            for m in store.list_objects(f"{ns}stream-output/{job_id}/")}


# ---------------------------------------------------------------------------
# Shared ingest: physical-once + byte parity
# ---------------------------------------------------------------------------

def test_two_tenants_one_physical_ingest_byte_identical_sinks():
    events = _events(n=600, seed=1)
    store = CountingStore()
    write_event_log(store, "gps/", events, segment_records=128)
    server = JobServer(store, MetadataStore())
    server.add_tenant("alice")
    server.add_tenant("bob")
    a = server.submit("alice", _program("shared-a", agg="sum"),
                      source_prefix="gps/")
    b = server.submit("bob", _program("shared-b", agg="count"),
                      source_prefix="gps/")
    states = server.run_until_complete()
    assert states == {a: JobStatus.DONE, b: JobStatus.DONE}

    # one SharedIngest, two subscribers, every segment fetched exactly once
    seg_reads = {k: c for k, c in store.gets.items()
                 if k.startswith("gps/segment-")}
    assert seg_reads, "the physical log was never read"
    assert all(c == 1 for c in seg_reads.values()), seg_reads
    ing = server.stats()["ingests"]["gps"]
    assert ing["pumped"] == len(events) and ing["subscribers"] == 2

    # each sink byte-identical to the tenant running alone
    assert _sink_bytes(store, "alice", "shared-a") == \
        _standalone(events, "shared-a", agg="sum")
    assert _sink_bytes(store, "bob", "shared-b") == \
        _standalone(events, "shared-b", agg="count")


def test_late_registering_job_replays_from_log_start():
    events = _events(n=400, seed=4)
    store = MemoryStore()
    write_event_log(store, "gps/", events, segment_records=64)
    server = JobServer(store, MetadataStore())
    server.add_tenant("alice")
    server.add_tenant("bob")
    server.submit("alice", _program("early-1"), source_prefix="gps/")
    server.step()                       # ingest fully materialized, alice ahead
    assert server.ingests["gps"].pumped == len(events)
    late = server.submit("bob", _program("late-1", agg="count"),
                         source_prefix="gps/")
    assert server.jobs[late].cursor == 0        # private cursor from the top
    server.run_until_complete()
    assert _sink_bytes(store, "alice", "early-1") == \
        _standalone(events, "early-1")
    assert _sink_bytes(store, "bob", "late-1") == \
        _standalone(events, "late-1", agg="count")


# ---------------------------------------------------------------------------
# Scale-to-zero lifecycle
# ---------------------------------------------------------------------------

def test_park_scales_to_zero_and_cold_restore_is_exactly_once():
    events = _events(n=400, seed=2, span=100.0)
    first, second = events[:250], events[250:]
    store = MemoryStore()
    write_event_log(store, "gps/", first, segment_records=64)
    server = JobServer(store, MetadataStore(),
                       park_policy=ParkPolicy(idle_seconds=0.0))
    server.add_tenant("alice")
    jid = server.submit("alice", _program("cold-1"), source_prefix="gps/")
    while server.step():
        pass
    job = server.jobs[jid]
    assert job.state == JobStatus.PARKED
    assert job.coord is None                    # carries freed
    assert server.pool.stats()["replicas"] == 0
    assert server.pool.stats()["scale_downs"] >= 1

    # the next matching events wake it: a timed cold restore
    write_event_log(store, "gps/", second, segment_records=64)
    states = server.run_until_complete()
    assert states[jid] == JobStatus.DONE
    rec = server.registry.record(jid)
    assert rec["parks"] >= 1 and rec["restores"] >= 1
    assert rec["cold_start_seconds"] > 0
    assert job.cold_start_latencies and all(
        t > 0 for t in job.cold_start_latencies)

    # exactly-once across the park/unpark round trip
    assert _sink_bytes(store, "alice", "cold-1") == \
        _standalone(events, "cold-1")


def test_crashed_server_reattaches_and_finishes_exactly_once():
    events = _events(n=500, seed=3)
    store = MemoryStore()
    meta = MetadataStore()
    write_event_log(store, "gps/", events[:300], segment_records=64)
    server = JobServer(store, meta,
                       park_policy=ParkPolicy(idle_seconds=0.0))
    server.add_tenant("alice")
    server.submit("alice", _program("crash-1"), source_prefix="gps/")
    while server.step():
        pass                # folds the available tail, parks with checkpoint
    assert server.jobs["crash-1"].state == JobStatus.PARKED
    del server              # the crash: all live state gone

    write_event_log(store, "gps/", events[300:], segment_records=64)
    server2 = JobServer(store, meta)    # fresh bus + pool, same store+meta
    server2.add_tenant("alice")
    server2.submit("alice", _program("crash-1"), source_prefix="gps/",
                   resume=True)
    states = server2.run_until_complete()
    assert states["crash-1"] == JobStatus.DONE
    assert _sink_bytes(store, "alice", "crash-1") == \
        _standalone(events, "crash-1")


# ---------------------------------------------------------------------------
# Tenancy: quotas and cross-job prefix claims
# ---------------------------------------------------------------------------

def test_quota_breach_fails_only_the_offending_tenant():
    events = _events(n=300, seed=5)
    store = MemoryStore()
    write_event_log(store, "gps/", events, segment_records=64)
    server = JobServer(store, MetadataStore())
    server.add_tenant("alice")
    server.add_tenant("cheap", quota_bytes=64)  # too small for any state
    a = server.submit("alice", _program("q-ok"), source_prefix="gps/")
    c = server.submit("cheap", _program("q-poor"), source_prefix="gps/")
    states = server.run_until_complete()
    assert states[a] == JobStatus.DONE
    assert states[c] == JobStatus.FAILED
    assert "QuotaExceeded" in server.jobs[c].error
    assert "QuotaExceeded" in server.status(c)["error"]
    # the neighbor is untouched
    assert _sink_bytes(store, "alice", "q-ok") == _standalone(events, "q-ok")


def test_quota_counts_replaced_objects_once():
    store = MemoryStore()
    server = JobServer(store, MetadataStore())
    t = server.add_tenant("tiny", quota_bytes=10)
    view = t.store_view(store)
    view.put("x", b"12345678")          # 8 of 10 bytes
    view.put("x", b"87654321")          # replacement frees the old 8 first
    with pytest.raises(QuotaExceeded):
        view.put("y", b"123")           # 8 + 3 > 10
    assert view.used_bytes() == 8


def test_cross_job_prefix_collision_rejected_at_submit():
    store = MemoryStore()
    server = JobServer(store, MetadataStore())
    server.add_tenant("alice")
    write_event_log(store, "gps/", _events(n=10), segment_records=8)
    server.submit("alice", _program("dup-1"), source_prefix="gps/")
    # same job id: globally unique, even per-tenant
    with pytest.raises(ValueError, match="already registered"):
        server.submit("alice", _program("dup-1"), source_prefix="gps/")
    # a sink nesting under an existing claim: prefix-listing overlap
    nested = (Pipeline.from_source(batch_records=100).key_by()
              .window(Windowing.tumbling(25.0)).reduce("sum")
              .sink("stream-output/dup-1/")
              .build(num_buckets=16, n_workers=W, batch_records=100,
                     job_id="dup-2"))
    with pytest.raises(PipelineError, match="collides"):
        server.submit("alice", nested, source_prefix="gps/")
    # distinct tenants namespace apart: same relative sink is fine
    server.add_tenant("bob")
    server.submit("bob", _program("dup-3"), source_prefix="gps/")


# ---------------------------------------------------------------------------
# Control plane: RPC skeleton + metadata-only client
# ---------------------------------------------------------------------------

def test_lifecycle_verbs_via_rpc_and_client():
    events = _events(n=300, seed=6)
    store = MemoryStore()
    write_event_log(store, "gps/", events[:150], segment_records=64)
    server = JobServer(store, MetadataStore())
    server.add_tenant("alice")
    rpc = JobRPC(server)
    client = JobServiceClient(server)

    assert rpc.handle({"method": "register", "name": "rollup",
                       "program": _program("life-1")})["ok"]
    resp = rpc.handle({"method": "submit", "tenant": "alice",
                       "program": "rollup", "source_prefix": "gps/"})
    assert resp["ok"]
    jid = resp["result"]
    assert jid == "life-1"
    assert client.status(jid)["state"] == JobStatus.PENDING

    server.step()
    assert client.status(jid)["state"] == JobStatus.RUNNING
    assert rpc.handle({"method": "pause", "job_id": jid})["result"] == \
        JobStatus.PAUSED

    # paused jobs do NOT wake on arriving events — only resume() does
    write_event_log(store, "gps/", events[150:], segment_records=64)
    while server.step():
        pass
    assert client.status(jid)["state"] == JobStatus.PAUSED
    assert server.status(jid)["lag"] > 0    # live field: server-side status

    assert rpc.handle({"method": "resume", "job_id": jid})["result"] == \
        JobStatus.RUNNING
    states = server.run_until_complete()
    assert states[jid] == JobStatus.DONE
    assert server.status(jid)["windows_emitted"] > 0
    assert client.jobs() == [jid]
    assert _sink_bytes(store, "alice", "life-1") == \
        _standalone(events, "life-1")

    # RPC edge: errors answer, they don't raise
    assert not rpc.handle({"method": "nope"})["ok"]
    bad = rpc.handle({"method": "status", "job_id": "ghost"})
    assert not bad["ok"] and "KeyError" in bad["error"]


def test_cancel_abandons_without_flush_and_keeps_claims():
    events = _events(n=200, seed=7)
    store = MemoryStore()
    write_event_log(store, "gps/", events, segment_records=64)
    server = JobServer(store, MetadataStore())
    server.add_tenant("alice")
    jid = server.submit("alice", _program("gone-1"), source_prefix="gps/")
    server.step()
    server.cancel(jid)
    states = server.run_until_complete()
    assert states[jid] == JobStatus.CANCELLED
    with pytest.raises(ValueError, match="already CANCELLED"):
        server.cancel(jid)
    # the cancelled job's prefix claim survives (its objects may too)
    with pytest.raises(PipelineError, match="collides"):
        server.submit("alice", (Pipeline.from_source(batch_records=100)
                                .key_by().window(Windowing.tumbling(25.0))
                                .reduce("sum").sink("stream-output/gone-1/")
                                .build(num_buckets=16, n_workers=W,
                                       batch_records=100, job_id="gone-2")),
                      source_prefix="gps/")
