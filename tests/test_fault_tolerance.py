"""Training-plane fault tolerance: preemption/restart continuity, transient
fault retries, elastic re-mesh (checkpoint written by N savers restored onto
M), and data-pipeline determinism across restarts."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.storage import MemoryStore
from repro.data import HashTokenizer, PackedLMDataset
from repro.data.pipeline import make_store_with_corpus
from repro.optim import AdamW
from repro.runtime import Trainer, TrainerConfig
from repro.runtime.trainer import PreemptionError
from repro.runtime.train_step import init_train_state

CFG = configs.get_reduced("qwen3-32b")


def _batches(seed=0):
    store, prefix = make_store_with_corpus(120_000, vocab_words=300,
                                           seed=seed)
    ds = PackedLMDataset(store, prefix, HashTokenizer(CFG.vocab), batch=4,
                         seq_len=16, seed=seed)
    return iter(ds)


@pytest.mark.slow
def test_preempt_restore_bitexact_continuation():
    opt = AdamW(lr=1e-3)
    store = MemoryStore()
    tc = TrainerConfig(checkpoint_every=5, log_every=5)

    # uninterrupted reference run
    ref = Trainer(CFG, opt, MemoryStore(), tcfg=tc, seed=0)
    ref_state = ref.run(_batches(), 14)

    # preempted at 7, resumed by a fresh trainer; data iterator replays the
    # same stream and the trainer skips consumed batches via start_step
    t1 = Trainer(CFG, opt, store, tcfg=tc, seed=0)
    with pytest.raises(PreemptionError):
        t1.run(_batches(), 14, preempt_at=7)
    t2 = Trainer(CFG, opt, store, tcfg=tc, seed=0)
    assert t2.start_step == 7
    it = _batches()
    for _ in range(7):                      # data-cursor replay
        next(it)
    state = t2.run(it, 14)

    for a, b in zip(jax.tree.leaves(ref_state.params),
                    jax.tree.leaves(state.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_transient_fault_is_retried():
    faults = {3}

    def hook(step):
        if step in faults:
            faults.discard(step)
            raise RuntimeError("flaky worker")

    t = Trainer(CFG, AdamW(lr=1e-3), MemoryStore(),
                tcfg=TrainerConfig(max_step_retries=2, checkpoint_every=100),
                fault_hook=hook)
    state = t.run(_batches(), 5)
    assert int(state.step) == 5


def test_fault_budget_exhaustion_raises():
    def hook(step):
        if step == 2:
            raise RuntimeError("dead node")

    t = Trainer(CFG, AdamW(lr=1e-3), MemoryStore(),
                tcfg=TrainerConfig(max_step_retries=1, checkpoint_every=100),
                fault_hook=hook)
    with pytest.raises(RuntimeError, match="dead node"):
        t.run(_batches(), 5)


def test_elastic_remesh_restore():
    """A checkpoint saved by 8 'hosts' restores onto 3 and training
    continues — the paper's stateless-worker elasticity on the train plane."""
    opt = AdamW(lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), CFG, opt)
    store = MemoryStore()
    save_checkpoint(store, "ckpt", 42, state, n_shards=8)
    restored, step = restore_checkpoint(store, "ckpt", state)
    assert step == 42
    # re-shard onto 3 "hosts": save again with a different layout
    save_checkpoint(store, "ckpt2", step, restored, n_shards=3)
    r2, _ = restore_checkpoint(store, "ckpt2", state)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(r2[0])):
        assert a.shape == b.shape


def test_data_pipeline_determinism():
    a = [b_["inputs"].sum() for _, b_ in zip(range(3), _batches(5))]
    b = [b_["inputs"].sum() for _, b_ in zip(range(3), _batches(5))]
    assert a == b
