"""The overlapped multi-tenant drive, ParkPolicy, and compute metering.

The claims under test:

* **Byte-identity** — the overlapped drive (per-job prefetch lanes
  multiplexed over one driver thread) emits exactly the serial
  round-robin's sink bytes for every tenant, property-tested over
  seeds, and still exactly-once when the server crashes mid-overlap
  with other tenants' batches prepared-but-unconsumed.
* **ParkPolicy** — parking is wall-clock + lag based: a drained job
  stays RUNNING until ``idle_seconds`` elapses, a parked job ignores
  backlog at or below ``max_lag`` and wakes above it, and the final
  bytes match a standalone run regardless.
* **Metering** — ``status()`` carries the job's compute bill
  (pool-seconds + fold invocations), persisted into the metadata
  records, and a tenant's ``quota_pool_seconds`` fails only that
  tenant's job.
* **Status fix** — a parked or crash-re-attached job reports its
  checkpointed offset, not the dead coordinator's in-memory cursor.
"""

import time
from collections import Counter

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                 # hermetic container
    from _hypothesis_compat import given, settings, strategies as st

import repro.service.server as server_mod
from repro.core import MemoryStore, MetadataStore
from repro.pipeline import Pipeline, Windowing
from repro.service import (ComputeQuotaExceeded, JobServer, JobStatus,
                           ParkPolicy)
from repro.streaming import (StreamSource, StreamingCoordinator,
                             write_event_log)

W = 4
_PROPERTY_SETTINGS = settings(max_examples=4, deadline=None)


class CountingStore(MemoryStore):
    def __init__(self):
        super().__init__()
        self.put_counts = Counter()

    def put(self, key, data):
        self.put_counts[key] += 1
        return super().put(key, data)


def _events(n=600, n_keys=5, span=120.0, seed=0, t0=0.0):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.uniform(t0, t0 + span, n))
    keys = rng.integers(0, n_keys, n)
    vals = rng.integers(0, 9, n).astype(float)
    return [(float(t), f"k{k}", float(v)) for t, k, v in zip(ts, keys, vals)]


def _program(job_id, *, agg="sum", batch_records=100):
    return (Pipeline.from_source(batch_records=batch_records).key_by()
            .window(Windowing.tumbling(25.0)).reduce(agg)
            .sink("stream-output/")
            .build(num_buckets=16, n_workers=W, batch_records=batch_records,
                   checkpoint_interval=2, job_id=job_id))


def _standalone(events, job_id, *, agg="sum", batch_records=100):
    built = _program(job_id, agg=agg, batch_records=batch_records)
    store = MemoryStore()
    coord = StreamingCoordinator(store, MetadataStore(), program=built)
    coord.run_stream(
        StreamSource.from_records(events, batch_records=batch_records))
    return {m.key: store.get(m.key)
            for m in store.list_objects(f"stream-output/{job_id}/")}


def _sink_bytes(store, tenant, job_id):
    ns = f"tenants/{tenant}/"
    return {m.key[len(ns):]: store.get(m.key)
            for m in store.list_objects(f"{ns}stream-output/{job_id}/")}


_TENANTS = (("alice", "sum"), ("bob", "count"), ("carol", "mean"))


def _run_service(events, *, overlap, store=None, resume=False,
                 server_kwargs=None):
    """All three tenants on one shared source, driven to completion."""
    store = store if store is not None else MemoryStore()
    if not resume:
        write_event_log(store, "gps/", events, segment_records=128)
    server = JobServer(store, MetadataStore(), overlap=overlap,
                       **(server_kwargs or {}))
    for name, agg in _TENANTS:
        server.add_tenant(name)
        server.submit(name, _program(f"ov-{name}", agg=agg),
                      source_prefix="gps/", resume=resume)
    states = server.run_until_complete()
    return store, states


# ---------------------------------------------------------------------------
# Overlapped drive: byte-identical to serial, property-tested
# ---------------------------------------------------------------------------

@_PROPERTY_SETTINGS
@given(st.integers(0, 2 ** 31 - 1))
def test_overlapped_drive_byte_identical_to_serial(seed):
    events = _events(n=700, seed=seed)
    serial_store, serial_states = _run_service(events, overlap=False)
    over_store, over_states = _run_service(events, overlap=True)
    assert set(serial_states.values()) == {JobStatus.DONE} == \
        set(over_states.values())
    for name, agg in _TENANTS:
        serial = _sink_bytes(serial_store, name, f"ov-{name}")
        assert serial, f"{name} emitted nothing"
        assert _sink_bytes(over_store, name, f"ov-{name}") == serial
        assert serial == _standalone(events, f"ov-{name}", agg=agg)


class _Boom(RuntimeError):
    pass


def _crashing_coordinator(crash_job, crash_after):
    """A coordinator class that raises mid-drive for one job only — with
    the overlapped drive on, the other tenants have batches prepared and
    sitting unconsumed in their prefetch lanes at that instant."""

    class _Crashing(StreamingCoordinator):
        def __init__(self, *args, **kwargs):
            super().__init__(*args, **kwargs)
            self._survived = 0

        def _process_prepared(self, prep, report):
            if self.prog.job_id == crash_job:
                if self._survived >= crash_after:
                    raise _Boom(f"injected crash before batch {prep.index}")
                self._survived += 1
            return super()._process_prepared(prep, report)

    return _Crashing


@_PROPERTY_SETTINGS
@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 5))
def test_crash_mid_overlap_reattaches_exactly_once(seed, crash_after):
    """Kill the server while the overlapped drive is in flight (one
    tenant's coordinator raises; the others' prefetch lanes hold
    prepared-but-unconsumed batches), then re-attach every job on a
    fresh server: every tenant's sink converges to the serial ground
    truth, each window object written exactly once."""
    events = _events(n=700, seed=seed)
    store = CountingStore()
    write_event_log(store, "gps/", events, segment_records=128)
    meta = MetadataStore()
    crash_job = f"ov-{_TENANTS[seed % len(_TENANTS)][0]}"

    server = JobServer(store, meta, overlap=True)
    for name, agg in _TENANTS:
        server.add_tenant(name)
        server.submit(name, _program(f"ov-{name}", agg=agg),
                      source_prefix="gps/")
    original = server_mod.StreamingCoordinator
    server_mod.StreamingCoordinator = _crashing_coordinator(crash_job,
                                                            crash_after)
    try:
        with pytest.raises(_Boom):
            while server.step():
                pass
            server.run_until_complete()
    finally:
        server_mod.StreamingCoordinator = original
    del server                                   # the crash: live state gone

    server2 = JobServer(store, meta, overlap=True)
    for name, agg in _TENANTS:
        server2.add_tenant(name)
        server2.submit(name, _program(f"ov-{name}", agg=agg),
                       source_prefix="gps/", resume=True)
    states = server2.run_until_complete()
    assert set(states.values()) == {JobStatus.DONE}
    for name, agg in _TENANTS:
        sink = _sink_bytes(store, name, f"ov-{name}")
        assert sink == _standalone(events, f"ov-{name}", agg=agg)
        for key in sink:
            full = f"tenants/{name}/{key}"
            assert store.put_counts[full] == 1, full


# ---------------------------------------------------------------------------
# ParkPolicy: wall-clock idleness + lag thresholds
# ---------------------------------------------------------------------------

def test_park_waits_out_idle_seconds_and_max_lag_batches_dribbles():
    events = _events(n=300, seed=11, span=60.0)
    store = MemoryStore()
    write_event_log(store, "gps/", events, segment_records=64)
    server = JobServer(store, MetadataStore(),
                       park_policy=ParkPolicy(idle_seconds=0.05, max_lag=8))
    server.add_tenant("alice")
    jid = server.submit("alice", _program("park-1"), source_prefix="gps/")
    while server.step():
        pass
    job = server.jobs[jid]
    # drained, but the idle clock has not run out — still RUNNING
    assert job.state == JobStatus.RUNNING
    time.sleep(0.06)
    server.step()
    assert job.state == JobStatus.PARKED
    assert server.pool.stats()["replicas"] == 0

    # a dribble at or below max_lag does NOT wake it (no cold start paid)
    dribble1 = _events(n=5, seed=12, span=10.0, t0=60.0)
    write_event_log(store, "gps/", dribble1, segment_records=64)
    server.step()
    assert job.state == JobStatus.PARKED
    assert server.status(jid)["lag"] == 5

    # crossing max_lag wakes it and the whole backlog drains
    dribble2 = _events(n=10, seed=13, span=10.0, t0=70.0)
    write_event_log(store, "gps/", dribble2, segment_records=64)
    server.step()
    assert job.state == JobStatus.RUNNING
    assert server.registry.record(jid)["restores"] >= 1
    states = server.run_until_complete()
    assert states[jid] == JobStatus.DONE
    assert _sink_bytes(store, "alice", "park-1") == \
        _standalone(events + dribble1 + dribble2, "park-1")


def test_park_policy_validates():
    with pytest.raises(ValueError, match="idle_seconds"):
        JobServer(MemoryStore(), MetadataStore(),
                  park_policy=ParkPolicy(idle_seconds=-1.0))
    with pytest.raises(ValueError, match="max_lag"):
        ParkPolicy(max_lag=-1).validate()


def test_per_job_park_policy_overrides_server_default():
    events = _events(n=200, seed=14)
    store = MemoryStore()
    write_event_log(store, "gps/", events, segment_records=64)
    # server default would never park in this test; the job's own policy
    # parks on the first idle observation
    server = JobServer(store, MetadataStore(),
                       park_policy=ParkPolicy(idle_seconds=60.0))
    server.add_tenant("alice")
    jid = server.submit("alice", _program("park-2"), source_prefix="gps/",
                        park_policy=ParkPolicy(idle_seconds=0.0))
    while server.step():
        pass
    server.step()
    assert server.jobs[jid].state == JobStatus.PARKED


# ---------------------------------------------------------------------------
# Compute metering + pool-time quotas
# ---------------------------------------------------------------------------

def test_status_reports_per_job_compute_bill():
    events = _events(n=400, seed=15)
    store = MemoryStore()
    write_event_log(store, "gps/", events, segment_records=64)
    server = JobServer(store, MetadataStore())
    server.add_tenant("alice")
    server.add_tenant("bob")
    a = server.submit("alice", _program("meter-a"), source_prefix="gps/")
    b = server.submit("bob", _program("meter-b", agg="count"),
                      source_prefix="gps/")
    server.run_until_complete()
    for jid in (a, b):
        s = server.status(jid)
        assert s["pool_seconds"] > 0
        assert s["fold_invocations"] > 0
        # persisted into the metadata record, so the metadata-only client
        # sees the same bill
        rec = server.registry.record(jid)
        assert rec["pool_seconds"] == s["pool_seconds"]
        assert rec["fold_invocations"] == s["fold_invocations"]
    # the meters split the one shared pool's accounting, not duplicate it
    total = server.pool.stats()["invocations"]
    metered = sum(j.meter.invocations for j in server.jobs.values())
    assert 0 < metered <= total


def test_pool_time_quota_fails_only_the_offending_tenant():
    events = _events(n=400, seed=16)
    store = MemoryStore()
    write_event_log(store, "gps/", events, segment_records=64)
    server = JobServer(store, MetadataStore())
    server.add_tenant("rich")
    server.add_tenant("broke", quota_pool_seconds=1e-9)
    r = server.submit("rich", _program("quota-ok"), source_prefix="gps/")
    p = server.submit("broke", _program("quota-poor", agg="count"),
                      source_prefix="gps/")
    states = server.run_until_complete()
    assert states[r] == JobStatus.DONE
    assert states[p] == JobStatus.FAILED
    assert "ComputeQuotaExceeded" in server.jobs[p].error
    assert "ComputeQuotaExceeded" in server.status(p)["error"]
    assert _sink_bytes(store, "rich", "quota-ok") == \
        _standalone(events, "quota-ok")


def test_compute_quota_exceeded_is_exported():
    assert issubclass(ComputeQuotaExceeded, RuntimeError)


# ---------------------------------------------------------------------------
# Status fix: parked / re-attached jobs report the checkpointed position
# ---------------------------------------------------------------------------

def test_reattached_job_status_reports_checkpointed_offset_not_zero():
    events = _events(n=400, seed=17)
    store = MemoryStore()
    meta = MetadataStore()
    write_event_log(store, "gps/", events[:300], segment_records=64)
    server = JobServer(store, meta,
                       park_policy=ParkPolicy(idle_seconds=0.0))
    server.add_tenant("alice")
    jid = server.submit("alice", _program("stat-1"), source_prefix="gps/")
    while server.step():
        pass
    assert server.jobs[jid].state == JobStatus.PARKED
    parked = server.status(jid)
    assert parked["cursor"] == 300 == parked["checkpointed_offset"]
    assert parked["lag"] == 0
    del server                                  # crash

    write_event_log(store, "gps/", events[300:], segment_records=64)
    server2 = JobServer(store, meta)
    server2.add_tenant("alice")
    server2.submit("alice", _program("stat-1"), source_prefix="gps/",
                   resume=True)
    # the regression: before its first drive the re-attached job's live
    # cursor is 0 — status must answer from the durable checkpoint
    s = server2.status(jid)
    assert s["cursor"] == 300 == s["checkpointed_offset"]
    server2.ingests["gps"].pump()
    assert server2.status(jid)["lag"] == 100
    states = server2.run_until_complete()
    assert states[jid] == JobStatus.DONE
    assert _sink_bytes(store, "alice", "stat-1") == \
        _standalone(events, "stat-1")
