"""planlint: golden diagnostics per rule (one trigger + one clean each),
the build-time warning integration, explain(), and admission — a
planlint-error program is rejected at ``JobServer.submit`` without
touching its neighbors."""

import dataclasses
import warnings

import pytest

from repro.analysis import PlanLintWarning, PlanRejected
from repro.analysis.planlint import (COLLISION_WARN_P, RAW_KEY_BITS,
                                     collision_probability,
                                     min_slots_required)
from repro.core import MemoryStore, MetadataStore
from repro.engine import stages as engine_stages
from repro.pipeline import Pipeline, RunOptions, Windowing
from repro.service import JobServer


def _build(*, window=None, reduce="sum", mode="aggregate", capacity=0,
           sink="out/", job_id="plt", **kw):
    w = window or Windowing.tumbling(10.0)
    kw.setdefault("num_buckets", 8)
    kw.setdefault("n_workers", 4)
    kw.setdefault("batch_records", 64)
    return (Pipeline.from_source(batch_records=kw["batch_records"])
            .key_by().window(w).reduce(reduce, mode=mode, capacity=capacity)
            .sink(sink).build(job_id=job_id, **kw))


def _replace_stage(built, si=0, **kw):
    stages = list(built.stages)
    stages[si] = dataclasses.replace(stages[si], **kw)
    return dataclasses.replace(built, stages=tuple(stages))


def _rules(diags, level=None):
    return [d.rule_id for d in diags
            if level is None or d.level == level]


# ---------------------------------------------------------------------------
# min_slots_required — the shared ring bound
# ---------------------------------------------------------------------------

def test_min_slots_required_golden():
    assert min_slots_required(10.0) == 2                   # tumbling
    assert min_slots_required(10.0, lateness=5.0) == 3
    assert min_slots_required(60.0, 20.0) == 4             # sliding
    assert min_slots_required(60.0, 20.0, 10.0) == 5


# ---------------------------------------------------------------------------
# PL001 — ring slots
# ---------------------------------------------------------------------------

def test_pl001_ring_too_small():
    bad = _replace_stage(_build(), n_slots=1)
    (d,) = [d for d in bad.check() if d.rule_id == "PL001"]
    assert d.level == "error" and d.loc == "stage 0"
    assert "n_slots=1 cannot hold the window span; need >= 2" in d.message
    assert "window ring full" in d.message


def test_pl001_session_single_slot():
    built = _build(window=Windowing.session(gap=5.0), reduce="mean")
    bad = _replace_stage(built, n_slots=1)
    (d,) = [d for d in bad.check() if d.rule_id == "PL001"]
    assert "session ring" in d.message and "need >= 2" in d.message


def test_pl001_clean():
    assert _build(n_slots=4).check() == []


# ---------------------------------------------------------------------------
# PL002 — hashed raw-id collisions
# ---------------------------------------------------------------------------

def test_pl002_birthday_bound_matches_engine():
    # the estimate is only honest if it models the actual wire id width
    assert RAW_KEY_BITS == engine_stages.RAW_KEY_BITS
    assert collision_probability(1) == 0.0
    assert 0.0 < collision_probability(100) < COLLISION_WARN_P
    assert collision_probability(1000) >= COLLISION_WARN_P


def test_pl002_hashed_warning_and_info():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PlanLintWarning)
        wide = _build(key_space="hashed", num_buckets=1000)
    (d,) = [d for d in wide.check() if d.rule_id == "PL002"]
    assert d.level == "warning"
    assert "24-bit raw-id space" in d.message and "silent merge" in d.message
    narrow = _build(key_space="hashed", num_buckets=64)
    (d,) = [d for d in narrow.check() if d.rule_id == "PL002"]
    assert d.level == "info"          # advisory only: explain() shows it


def test_pl002_dense_clean():
    assert "PL002" not in _rules(_build(num_buckets=1000).check())


# ---------------------------------------------------------------------------
# PL003 — group capacity vs one micro-batch
# ---------------------------------------------------------------------------

def test_pl003_capacity_below_batch_floor():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PlanLintWarning)
        built = _build(reduce="max", mode="group", capacity=8,
                       batch_records=256)
    (d,) = [d for d in built.check() if d.rule_id == "PL003"]
    assert d.level == "warning"
    assert "capacity=8" in d.message and "64 records" in d.message
    assert "capacity_dropped" in d.message


def test_pl003_clean():
    built = _build(reduce="max", mode="group", capacity=64,
                   batch_records=256)
    assert "PL003" not in _rules(built.check())


# ---------------------------------------------------------------------------
# PL004 — watermark wiring
# ---------------------------------------------------------------------------

def _two_stage():
    return (Pipeline.from_source(batch_records=64).key_by()
            .window(Windowing.tumbling(10.0)).reduce("count")
            .window(Windowing.tumbling(60.0)).reduce("sum")
            .sink("out/")
            .build(num_buckets=8, n_workers=4, batch_records=64,
                   job_id="plt4"))


def test_pl004_unfed_side_is_error():
    bad = dataclasses.replace(_two_stage(), inputs=())
    diags = [d for d in bad.check() if d.rule_id == "PL004"]
    (d,) = [d for d in diags if d.level == "error"]
    assert d.loc == "stage 0"
    assert "no input channel" in d.message and "-inf" in d.message


def test_pl004_dead_lateness_on_carry_fed_stage():
    bad = _replace_stage(_two_stage(), si=1, allowed_lateness=3.0)
    (d,) = [d for d in bad.check() if d.rule_id == "PL004"]
    assert d.level == "warning" and d.loc == "stage 1"
    assert "fed only through the carry" in d.message


def test_pl004_clean():
    assert _two_stage().check() == []


# ---------------------------------------------------------------------------
# PL005 — sink prefixes
# ---------------------------------------------------------------------------

def test_pl005_nested_sinks_across_branches():
    # build-time distinctness only rejects exact duplicate sinks; overlap
    # of the *normalized* `<sink>/<job_id>/` prefixes is planlint's
    # generalization — one branch's sink nests under the other branch's
    # job prefix, so a prefix listing of one sees the other's windows
    fan = (Pipeline.from_source(batch_records=64).key_by()
           .window(Windowing.tumbling(10.0)).reduce("count")
           .tee(Pipeline.branch().window(Windowing.tumbling(60.0))
                .reduce("sum").sink("acc/"),
                Pipeline.branch().window(Windowing.tumbling(60.0))
                .reduce("sum").sink("acc/plt5/deep/")))
    with pytest.warns(PlanLintWarning, match="PL005"):
        built = fan.build(num_buckets=8, n_workers=4, batch_records=64,
                          job_id="plt5")
    (d,) = [d for d in built.check() if d.rule_id == "PL005"]
    assert d.level == "error" and "overlap" in d.message


def test_pl005_reserved_jobs_namespace():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PlanLintWarning)
        built = _build(sink="jobs/")
    (d,) = [d for d in built.check() if d.rule_id == "PL005"]
    assert "reserved" in d.message and "carry checkpoint" in d.message


def test_pl005_sink_under_source_log():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PlanLintWarning)
        built = (Pipeline.from_source(prefix="streams/gps", batch_records=64)
                 .key_by().window(Windowing.tumbling(10.0)).reduce("sum")
                 .sink("streams/gps/rollup/")
                 .build(num_buckets=8, n_workers=4, batch_records=64,
                        job_id="plt5s"))
    (d,) = [d for d in built.check() if d.rule_id == "PL005"]
    assert "ingest its own output" in d.message
    # the same overlap arrives via a run-time source binding too
    clean = _build(sink="rollup/")
    assert clean.check() == []
    diags = clean.check(source_prefixes=("rollup/",))
    assert _rules(diags, "error") == ["PL005"]


# ---------------------------------------------------------------------------
# PL006 — donation
# ---------------------------------------------------------------------------

def test_pl006_donate_under_jit_false():
    built = _build(jit=False)
    assert built.check() == []                       # silent without opts
    diags = built.check(RunOptions(donate_carry=True))
    (d,) = [d for d in diags if d.rule_id == "PL006"]
    assert d.level == "warning" and "silently unavailable" in d.message


def test_pl006_join_shared_carry_info():
    right = (Pipeline.from_source(batch_records=64).key_by()
             .window(Windowing.tumbling(10.0)).reduce("sum"))
    built = (Pipeline.from_source(batch_records=64).key_by()
             .window(Windowing.tumbling(10.0)).reduce("sum")
             .join(right)
             .sink("out/")
             .build(num_buckets=8, n_workers=4, batch_records=64,
                    job_id="plt6"))
    (d,) = [d for d in built.check(RunOptions(donate_carry=True))
            if d.rule_id == "PL006"]
    assert d.level == "info" and "shared carry" in d.message
    assert "PL006" not in _rules(built.check())      # no donation, no flag


# ---------------------------------------------------------------------------
# integrations: build warns, explain reports, submit rejects
# ---------------------------------------------------------------------------

def test_build_emits_planlint_warnings():
    with pytest.warns(PlanLintWarning, match="PL003"):
        _build(reduce="max", mode="group", capacity=4, batch_records=256)


def test_clean_build_warns_nothing():
    with warnings.catch_warnings():
        warnings.simplefilter("error", PlanLintWarning)
        _build()


def test_explain_lists_stages_and_findings():
    text = _build(n_slots=4).explain()
    assert "tumbling(10.0)" in text and "planlint: clean" in text
    bad = _replace_stage(_build(), n_slots=1)
    assert "PL001" in bad.explain()


def test_submit_rejects_only_the_offending_tenant():
    srv = JobServer(MemoryStore(), MetadataStore())
    srv.add_tenant("good-co")
    srv.add_tenant("bad-co")
    ok = _build(job_id="ok-job")
    srv.submit("good-co", ok, source_prefix="events/")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PlanLintWarning)
        bad = _build(sink="jobs/", job_id="bad-job")
    with pytest.raises(PlanRejected) as exc:
        srv.submit("bad-co", bad, source_prefix="events/")
    assert "PL005" in str(exc.value)
    assert [d.rule_id for d in exc.value.diagnostics] == ["PL005"]
    # the neighbor's job is untouched and the bad job never registered
    assert srv.status("ok-job")["state"] is not None
    assert "bad-job" not in srv.jobs
    with pytest.raises(KeyError):
        srv.status("bad-job")
