"""The pipelined streaming runtime and its one front door.

Covers the three-lane scheduler (prepare/fold/drain): byte-identity of
overlapped vs synchronous drives on every tee branch, exactly-once
crash/restore with a batch prepared-but-unconsumed in the prefetch queue,
batched sink writes (one ``put_many`` round trip per finalization sweep,
identical bytes), carry-donation parity, the ``RunOptions`` knob block,
``BuiltPipeline.run``'s dispatch by source kind, key-space sharding, and
the hard removal of the pre-Pipeline shims.
"""

import json
from collections import Counter

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                 # hermetic container
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import MemoryStore, MetadataStore
from repro.pipeline import JoinSource, Pipeline, RunOptions, Windowing
from repro.streaming import StreamingCoordinator, StreamSource

W = 4
_PROPERTY_SETTINGS = settings(max_examples=4, deadline=None)

#: every scheduler lane off — the synchronous pre-async drive loop
SYNC = RunOptions(overlap=False, sink_batching=False, donate_carry=False)


def _events(n=1500, n_keys=6, span=200.0, seed=0, vmax=9):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.uniform(0, span, n))
    keys = rng.integers(0, n_keys, n)
    vals = rng.integers(0, vmax, n).astype(float)   # ints exact in fp32
    return [(float(t), f"k{k}", float(v)) for t, k, v in zip(ts, keys, vals)]


def _region(rec):
    ts, key, value = rec
    return ts, ("even" if int(key[1:]) % 2 == 0 else "odd"), value


def _tee_pipeline(events, *, batch_records=150):
    """Counts per 10 s teed into a top-k branch (device edge) and a
    per-region rollup branch (host edge) — both transports under test."""
    base = (Pipeline.from_source(records=events, batch_records=batch_records)
            .key_by().window(Windowing.tumbling(10.0)).reduce("count"))
    return base.tee(
        Pipeline.branch().window(Windowing.tumbling(50.0)).reduce("sum")
                .top_k(3).sink("async-top/"),
        Pipeline.branch().map(_region).key_by()
                .window(Windowing.tumbling(50.0)).reduce("sum")
                .sink("async-region/"))


def _chain_pipeline(events, *, batch_records=100, job_id="async-chain"):
    return (Pipeline.from_source(records=events, batch_records=batch_records)
            .key_by().window(10.0).reduce("sum").sink("async-out/")
            .build(num_buckets=8, n_workers=W, job_id=job_id))


def _stream(built, store, options, *, events=None, batch_records=100,
            meta=None, flush=True):
    src = (StreamSource.from_records(events, batch_records=batch_records)
           if events is not None else None)
    return built.run(src, store=store, meta=meta, options=options,
                     mode="streaming", flush=flush)


# ---------------------------------------------------------------------------
# Determinism: every lane combination emits the same bytes
# ---------------------------------------------------------------------------

def test_overlap_matches_sync_byte_identical_on_all_branches():
    """The acceptance criterion: the overlapped scheduler (prefetch +
    deferred stats + batched sinks + donated carries) emits bit-identical
    window bytes to the synchronous drive, on both branches of a tee."""
    events = _events(n=2000, seed=41)
    built = _tee_pipeline(events).build(num_buckets=12, n_workers=W,
                                        job_id="async-tee")
    sync_store, async_store = MemoryStore(), MemoryStore()
    _stream(built, sync_store, SYNC)
    report = _stream(built, async_store, RunOptions(overlap=True))
    sync_out = built.collect_outputs(sync_store)
    async_out = built.collect_outputs(async_store)
    assert sync_out and async_out == sync_out       # byte for byte
    assert {k.split("/", 1)[0] for k in async_out} \
        == {"async-top", "async-region"}
    # the drain lane records close→emit latency for every emitted window
    assert len(report.emit_latencies) == report.windows_emitted > 0
    assert report.p99_emit_latency >= report.p50_emit_latency >= 0.0


@pytest.mark.parametrize("knob", ["overlap", "sink_batching", "donate_carry"])
def test_each_lane_alone_is_byte_identical(knob):
    """Each scheduler knob toggled on its own changes no output byte —
    the lanes are pure scheduling, never semantics."""
    events = _events(n=800, seed=43)
    built = _chain_pipeline(events, job_id=f"async-{knob}")
    ref_store, got_store = MemoryStore(), MemoryStore()
    _stream(built, ref_store, SYNC)
    one_on = RunOptions(**{**{"overlap": False, "sink_batching": False,
                              "donate_carry": False}, knob: True})
    _stream(built, got_store, one_on)
    ref = built.collect_outputs(ref_store)
    assert ref and built.collect_outputs(got_store) == ref


# ---------------------------------------------------------------------------
# Exactly-once across a mid-prefetch crash
# ---------------------------------------------------------------------------

class _Boom(RuntimeError):
    pass


class CrashingCoordinator(StreamingCoordinator):
    """Crashes before processing micro-batch ``crash_batch`` — with the
    prefetcher on, later batches are already host-prepared and sitting
    unconsumed in the queue at that instant."""

    def __init__(self, *args, crash_batch, **kwargs):
        super().__init__(*args, **kwargs)
        self._crash_batch = crash_batch
        self._processed = 0

    def _process_prepared(self, prep, report):
        if self._processed >= self._crash_batch:
            raise _Boom(f"injected crash before batch {prep.index}")
        super()._process_prepared(prep, report)
        self._processed += 1


class CountingStore(MemoryStore):
    def __init__(self):
        super().__init__()
        self.put_counts = Counter()
        self.put_many_calls = []

    def put(self, key, data):
        self.put_counts[key] += 1
        return super().put(key, data)

    def put_many(self, items):
        self.put_many_calls.append(len(items))
        return super().put_many(items)


def _check_crash_restore(overlap: bool, seed: int, crash_batch: int) -> None:
    """Crash while batch N folds and batch N+1 sits prepared in the
    prefetch queue; a fresh coordinator restores from the checkpoint and
    the stream converges to the uninterrupted run byte for byte on
    *every* tee branch — each window object written exactly once, none
    lost, with the overlapped and the synchronous loop alike (the record
    offset only advances at the micro-batch barrier, so
    prepared-but-unconsumed batches replay from the log)."""
    events = _events(n=1000, n_keys=5, span=200.0, seed=seed)
    opts = (RunOptions(prefetch_batches=2) if overlap else SYNC)

    def build():
        return _tee_pipeline(events, batch_records=100).build(
            num_buckets=12, n_workers=W, checkpoint_interval=2,
            job_id="async-crash")

    ref_store = MemoryStore()
    _stream(build(), ref_store, opts, events=events)
    ref = build().collect_outputs(ref_store)

    store, meta = CountingStore(), MetadataStore()
    dead = CrashingCoordinator(store, meta, program=build(), options=opts,
                               crash_batch=crash_batch)
    with pytest.raises(_Boom):
        dead.run_stream(StreamSource.from_records(events, batch_records=100),
                        announce=False, flush=False)
    report = _stream(build(), store, opts, events=events, meta=meta)
    assert report.error is None
    got = build().collect_outputs(store)
    assert got == ref                               # no lost windows
    for key in ref:
        assert store.put_counts[key] == 1, key      # no duplicates either


@_PROPERTY_SETTINGS
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8))
def test_mid_prefetch_crash_restores_exactly_once(seed, crash_batch):
    _check_crash_restore(True, seed, crash_batch)


@_PROPERTY_SETTINGS
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8))
def test_mid_stream_crash_restores_exactly_once_sync(seed, crash_batch):
    _check_crash_restore(False, seed, crash_batch)


# ---------------------------------------------------------------------------
# Batched sinks: one store round trip per finalization sweep
# ---------------------------------------------------------------------------

def test_sink_batching_one_round_trip_per_sweep_same_bytes():
    """With ``sink_batching`` on, every window emitted during one
    finalization sweep lands through a single ``put_many`` round trip;
    the per-object writes (and their bytes) are unchanged because the
    base ``put_many`` loops ``put``."""
    events = _events(n=1200, n_keys=8, span=300.0, seed=47)
    built = _chain_pipeline(events, batch_records=600, job_id="async-sink")

    plain = MemoryStore()
    _stream(built, plain, SYNC, batch_records=600)
    ref = built.collect_outputs(plain)

    counting = CountingStore()
    _stream(built, counting, RunOptions(overlap=False, donate_carry=False),
            batch_records=600)
    got = built.collect_outputs(counting)
    assert ref and got == ref                       # bytes identical
    # every window went through the batched path, in sweep-sized groups
    window_keys = [k for k in counting.put_counts if k in ref]
    assert sum(counting.put_many_calls) == len(window_keys)
    assert max(counting.put_many_calls) >= 2        # a real multi-put sweep
    for key in ref:
        assert counting.put_counts[key] == 1        # base put_many loops put


def test_checkpoint_never_passes_staged_writes():
    """The barrier invariant: a checkpoint with staged-but-unwritten sink
    bytes would lose windows on crash, so the coordinator refuses it."""
    events = _events(n=300, seed=49)
    built = _chain_pipeline(events, job_id="async-barrier")
    coord = StreamingCoordinator(MemoryStore(), MetadataStore(),
                                 program=built, options=RunOptions())
    coord._pending_puts.append(("k", b"x", 0.0, 1.0, 1, 0.0))
    with pytest.raises(RuntimeError, match="undrained lane"):
        coord._save_state()


# ---------------------------------------------------------------------------
# RunOptions: validation and the shim removal boundary
# ---------------------------------------------------------------------------

def test_run_options_validation():
    RunOptions().validate()                         # defaults are valid
    RunOptions(prefetch_batches=1, checkpoint_interval=0,
               shard=(2, 3)).validate()
    with pytest.raises(ValueError, match="prefetch_batches"):
        RunOptions(prefetch_batches=0).validate()
    with pytest.raises(ValueError, match="checkpoint_interval"):
        RunOptions(checkpoint_interval=-1).validate()
    for bad in [(3, 3), (-1, 2), (0, 0)]:
        with pytest.raises(ValueError, match="shard"):
            RunOptions(shard=bad).validate()


def test_pre_pipeline_shims_are_gone():
    """``StreamingConfig`` and one-shot ``mapreduce()`` were removed in
    PR 8; the coordinator demands a compiled program and the error points
    at what replaced the shim."""
    import repro.core.mapreduce as mr
    import repro.streaming as streaming
    assert not hasattr(streaming, "StreamingConfig")
    assert not hasattr(mr, "mapreduce")
    with pytest.raises(ValueError, match="StreamingConfig shim was removed"):
        StreamingCoordinator(MemoryStore(), MetadataStore(), program=None)


# ---------------------------------------------------------------------------
# run(): dispatch by source kind
# ---------------------------------------------------------------------------

def test_run_dispatches_records_to_batch_and_streams_to_streaming():
    events = _events(n=600, seed=53)
    built = _chain_pipeline(events, job_id="async-dispatch")
    # records-bound graph, no argument → one-shot batch
    outs, report = built.run()
    assert outs and report.batches == 1             # one_shot: a single fold
    # a live StreamSource → streaming (micro-batches), same bytes
    store = MemoryStore()
    rep2 = built.run(StreamSource.from_records(events, batch_records=100),
                     store=store)
    assert rep2.batches == 6
    assert sorted(built.collect_outputs(store).values()) \
        == sorted(outs.values())
    # mode= pins the dispatch: records stream when forced
    store3 = MemoryStore()
    rep3 = built.run(store=store3, mode="streaming")
    assert rep3.batches == 6
    with pytest.raises(ValueError, match="mode"):
        built.run(mode="sideways")


def test_run_dispatches_array_pipeline_to_batch_plan():
    def map_fn(shard):
        n = shard.shape[0]
        return (np.arange(n, dtype=np.int32) % 4, shard[:, 0],
                np.ones(n, np.float32))

    data = np.arange(2 * 8 * 3, dtype=np.float32).reshape(2, 8, 3)
    built = (Pipeline.from_source(shards=data).map(map_fn).reduce("sum")
             .build(num_buckets=4, n_workers=2))
    result, _stats = built.run()                    # bound shards
    result2, _stats2 = built.run(data)              # explicit data
    np.testing.assert_allclose(np.asarray(result), np.asarray(result2))
    with pytest.raises(ValueError, match="no streaming mode"):
        built.run(data, mode="streaming")


def test_run_accepts_join_pair_and_join_source():
    left = _events(n=400, seed=57)
    right = _events(n=400, seed=58)
    built = (Pipeline.from_source(records=left, batch_records=100)
             .key_by().window(20.0).reduce("sum")
             .join(Pipeline.from_source(records=right, batch_records=100)
                   .key_by().window(20.0).reduce("sum"))
             .sink("async-join/")
             .build(num_buckets=8, n_workers=W, job_id="async-join"))
    outs, _report = built.run((left, right))        # pair of lists → batch
    store = MemoryStore()
    merged = JoinSource(StreamSource.from_records(left, batch_records=100),
                        StreamSource.from_records(right, batch_records=100),
                        batch_records=100)
    built.run(merged, store=store)                  # JoinSource → streaming
    assert outs and sorted(built.collect_outputs(store).values()) \
        == sorted(outs.values())


def test_checkpoint_interval_override_reaches_coordinator():
    """``RunOptions.checkpoint_interval`` overrides the program's spacing
    for one run without rebuilding the pipeline."""
    events = _events(n=500, seed=59)
    built = _chain_pipeline(events, job_id="async-ckpt")   # program: every batch
    store, meta = MemoryStore(), MetadataStore()
    _stream(built, store, RunOptions(checkpoint_interval=0),
            events=events, meta=meta, flush=False)
    coord = StreamingCoordinator(store, meta, program=built)
    assert coord.checkpointed_offset() == 0         # 0 disables checkpoints
    _stream(built, store, RunOptions(checkpoint_interval=2),
            events=events, meta=meta, flush=False)
    coord = StreamingCoordinator(store, meta, program=built)
    assert coord.checkpointed_offset() == 400       # batch 4 of 5, interval 2


# ---------------------------------------------------------------------------
# Sharding: partition the key space, union the outputs
# ---------------------------------------------------------------------------

def _rows(outputs):
    """window name → {key: value} across all of a run's output objects."""
    rows = {}
    for k, blob in outputs.items():
        name = k.rsplit("/", 1)[1]
        for ln in blob.splitlines():
            key, val = json.loads(ln)
            rows.setdefault(name, {})[key] = val
    return rows


def test_shard_union_equals_unsharded_run():
    """``shard=(i, n)`` drives one key partition under a suffixed job id;
    the shards' rows union — disjointly — to the unsharded run's."""
    events = _events(n=1000, n_keys=6, seed=61)
    built = _chain_pipeline(events, job_id="async-shard")
    full = MemoryStore()
    _stream(built, full, RunOptions())
    want = _rows(built.collect_outputs(full))

    union, seen_keys = {}, []
    for i in range(3):
        store = MemoryStore()
        _stream(built, store, RunOptions(shard=(i, 3)))
        outs = {m.key: store.get(m.key)
                for m in store.list_objects("async-out/")}
        assert all(f"async-shard-shard{i}of3/" in k for k in outs)
        part = _rows(outs)
        for name, per_key in part.items():
            overlap = set(per_key) & set(union.get(name, {}))
            assert not overlap                      # partitions are disjoint
            union.setdefault(name, {}).update(per_key)
        seen_keys.append({k for per in part.values() for k in per})
    assert union == want                            # union == the whole
    assert sum(map(len, seen_keys)) == len(set().union(*seen_keys))


def test_shard_rejects_joins_and_arrays():
    left = _events(n=100, seed=63)
    joined = (Pipeline.from_source(records=left, batch_records=50)
              .key_by().window(20.0).reduce("sum")
              .join(Pipeline.from_source(records=left, batch_records=50)
                    .key_by().window(20.0).reduce("sum"))
              .sink("sj/").build(num_buckets=8, n_workers=2, job_id="sj"))
    with pytest.raises(ValueError, match="single-input"):
        joined.run((left, left), options=RunOptions(shard=(0, 2)))

    def map_fn(shard):
        n = shard.shape[0]
        return (np.zeros(n, np.int32), shard[:, 0], np.ones(n, np.float32))

    arr = (Pipeline.from_source(shards=np.ones((2, 4, 2), np.float32))
           .map(map_fn).reduce("sum").build(num_buckets=4, n_workers=2))
    with pytest.raises(ValueError, match="shard"):
        arr.run(options=RunOptions(shard=(0, 2)))
