"""docsmoke: snippet extraction, skip markers, shared namespaces,
failure reporting — and the sweep regression that the shipped docs
actually run (the executable-documentation contract CI enforces)."""

import pathlib
import textwrap

from repro.analysis.docsmoke import (extract_snippets, main, run_file,
                                     run_paths)

REPO = pathlib.Path(__file__).resolve().parent.parent


def _md(src):
    return textwrap.dedent(src)


def test_extracts_python_fences_only_with_lines():
    text = _md("""\
        # Title

        ```python
        x = 1
        ```

        ```bash
        echo not-python
        ```

        ```
        bare fence: prose
        ```

        ```python
        y = x + 1
        ```
        """)
    snips = extract_snippets(text, "doc.md")
    assert [(s.line, s.source) for s in snips] == [(3, "x = 1"),
                                                   (15, "y = x + 1")]


def test_skip_marker_drops_the_next_block():
    text = _md("""\
        <!-- docsmoke: skip -->
        ```python
        raise RuntimeError("illustrative only")
        ```

        ```python
        ok = True
        ```
        """)
    snips = extract_snippets(text, "doc.md")
    assert len(snips) == 1 and snips[0].source == "ok = True"


def test_blocks_share_a_namespace_and_failures_carry_position(tmp_path):
    good = tmp_path / "good.md"
    good.write_text(_md("""\
        ```python
        acc = [1]
        ```
        later prose
        ```python
        acc.append(2)
        assert acc == [1, 2]
        ```
        """))
    assert run_file(good) == []

    bad = tmp_path / "bad.md"
    bad.write_text("line1\n\n```python\nboom()\n```\n")
    (report,) = run_file(bad)
    assert report.startswith(f"{bad}:3: snippet raised")
    assert "NameError" in report


def test_cli_exit_codes_and_directory_recursion(tmp_path, capsys):
    docs = tmp_path / "docs"
    docs.mkdir()
    (docs / "a.md").write_text("```python\nx = 1\n```\n")
    (docs / "b.md").write_text("no snippets here\n")
    assert main([str(docs)]) == 0
    assert "2 file(s), 0 failure(s)" in capsys.readouterr().out
    (docs / "c.md").write_text("```python\n1 / 0\n```\n")
    assert main([str(docs)]) == 1
    out = capsys.readouterr()
    assert "ZeroDivisionError" in out.err


def test_shipped_docs_run_clean():
    n, failures = run_paths([REPO / "README.md", REPO / "docs"])
    assert n >= 3            # README + architecture + operations at least
    assert failures == [], "\n".join(failures)
