"""The fused Pallas streaming-fold backend (`backend="pallas"`).

One kernel (``kernels/fused_fold``) replaces the XLA hash → window fan-out
→ scatter-accumulate chain inside ``CompiledStreamAggregate.step``.  These
tests pin the dispatch seam: the pallas backend (interpret mode — the
kernel body executes on this CPU container) must be **byte-identical** to
the ``vmap`` backend across the whole dispatch matrix — tumbling/sliding ×
dense/hashed key spaces × overlap on/off — and through every plan shape
the streaming engine runs (sessions' host wire, top-k, tee fan-out, joins
sharing one carry at a nonzero channel base), including exactly-once
crash/restore through a pallas-compiled plan (the ``test_async_runtime``
harness, re-aimed).  Kernel-vs-ref parity lives in ``test_kernels.py``;
this file owns plan- and pipeline-level parity.
"""

import numpy as np
import pytest

from test_async_runtime import (SYNC, W, CountingStore, CrashingCoordinator,
                                _Boom, _events, _region, _stream)

from repro.core import MemoryStore, MetadataStore
from repro.engine.plan import (ExecutionPlan, KeySpace, ReduceSpec,
                               WindowSpec)
from repro.pipeline import JoinSource, Pipeline, RunOptions, Windowing
from repro.streaming import StreamSource

TUMBLING = Windowing.tumbling(10.0)
SLIDING = Windowing.sliding(20.0, 5.0)


def _chain(events, *, windowing, hashed, batch_records=100):
    p = (Pipeline.from_source(records=events, batch_records=batch_records)
         .key_by().window(windowing).reduce("sum").sink("pal/"))
    kw = dict(num_buckets=8, n_workers=W, job_id="pal")
    if hashed:
        kw["key_space"] = "hashed"
    return p, kw


def _collect(p, kw, backend, events, options, batch_records=100):
    built = p.build(backend=backend, **kw)
    store = MemoryStore()
    _stream(built, store, options, events=events,
            batch_records=batch_records)
    return built.collect_outputs(store)


# ---------------------------------------------------------------------------
# The dispatch matrix: windowing × key space × overlap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("windowing", [TUMBLING, SLIDING],
                         ids=["tumbling", "sliding"])
@pytest.mark.parametrize("hashed", [False, True], ids=["dense", "hashed"])
def test_pallas_matches_vmap_byte_identical(windowing, hashed):
    """Every (window kind, key space, overlap) cell: same window objects,
    same bytes.  Integer-valued test values make float32 sums exact, so
    the kernel's sequential-tile accumulation cannot drift from the
    reduce_scatter's."""
    events = _events(n=1200, seed=11)
    p, kw = _chain(events, windowing=windowing, hashed=hashed)
    ref = _collect(p, kw, "vmap", events, SYNC)
    assert ref
    for overlap in (False, True):
        opts = RunOptions(overlap=True) if overlap else SYNC
        got = _collect(p, kw, "pallas", events, opts)
        assert got == ref


def test_pallas_sessions_host_wire():
    """Session windows ship the 4-column host wire (fan-out 1) — the
    kernel's host_wire decode path, plus the carry cell ops."""
    events = _events(n=900, n_keys=4, span=300.0, seed=13)
    p = (Pipeline.from_source(records=events, batch_records=100)
         .key_by().window(Windowing.session(3.0)).reduce("sum")
         .sink("sess/"))
    kw = dict(num_buckets=8, n_workers=W, job_id="pal-sess")
    ref = _collect(p, kw, "vmap", events, SYNC)
    got = _collect(p, kw, "pallas", events, RunOptions(overlap=True))
    assert ref and got == ref


def test_pallas_top_k_and_tee_branches():
    """A teed DAG — top-k on the device-handoff branch, per-region rollup
    on the host-record branch — emits the same bytes on every branch."""
    events = _events(n=1200, seed=17)
    base = (Pipeline.from_source(records=events, batch_records=150)
            .key_by().window(Windowing.tumbling(10.0)).reduce("count"))
    p = base.tee(
        Pipeline.branch().window(Windowing.tumbling(50.0)).reduce("sum")
                .top_k(3).sink("pal-top/"),
        Pipeline.branch().map(_region).key_by()
                .window(Windowing.tumbling(50.0)).reduce("sum")
                .sink("pal-region/"))
    kw = dict(num_buckets=12, n_workers=W, job_id="pal-tee")
    ref = _collect(p, kw, "vmap", events, SYNC, batch_records=150)
    got = _collect(p, kw, "pallas", events, RunOptions(overlap=True),
                   batch_records=150)
    assert ref and got == ref
    assert {k.split("/", 1)[0] for k in ref} == {"pal-top", "pal-region"}


def test_pallas_join_shared_carry():
    """Two joined plans share one carry at disjoint channel bases — the
    kernel's channel embedding must leave the other side's channels
    untouched, batch after batch."""
    left_ev = _events(n=800, seed=19)
    right_ev = _events(n=800, seed=23)
    left = (Pipeline.from_source(records=left_ev, batch_records=100)
            .key_by().window(Windowing.tumbling(20.0)).reduce("sum"))
    right = (Pipeline.from_source(records=right_ev, batch_records=100)
             .key_by().window(Windowing.tumbling(20.0)).reduce("count"))
    p = left.join(right).sink("pal-join/")

    def run(backend):
        built = p.build(num_buckets=8, n_workers=W, job_id="pal-join",
                        backend=backend)
        store = MemoryStore()
        src = JoinSource(
            StreamSource.from_records(left_ev, batch_records=100),
            StreamSource.from_records(right_ev, batch_records=100), 100)
        built.run(src, store=store, options=RunOptions(overlap=True),
                  mode="streaming")
        return built.collect_outputs(store)

    ref, got = run("vmap"), run("pallas")
    assert ref and got == ref


# ---------------------------------------------------------------------------
# Exactly-once crash/restore through a pallas-compiled plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("overlap", [False, True], ids=["sync", "overlap"])
def test_pallas_crash_restore_exactly_once(overlap):
    """Kill the coordinator mid-stream and restore from the checkpoint:
    the pallas-compiled plan converges to the uninterrupted vmap run byte
    for byte, each window written exactly once — the carry checkpoints
    (flat slab layout) round-trip through the fused kernel unchanged."""
    events = _events(n=1000, n_keys=5, span=200.0, seed=29)
    opts = RunOptions(prefetch_batches=2) if overlap else SYNC

    def build(backend="pallas"):
        p = (Pipeline.from_source(records=events, batch_records=100)
             .key_by().window(Windowing.sliding(20.0, 5.0)).reduce("sum")
             .sink("pal-crash/"))
        return p.build(num_buckets=8, n_workers=W, checkpoint_interval=2,
                       job_id="pal-crash", backend=backend)

    vmap_store = MemoryStore()
    _stream(build("vmap"), vmap_store, opts, events=events)
    ref = build("vmap").collect_outputs(vmap_store)

    store, meta = CountingStore(), MetadataStore()
    dead = CrashingCoordinator(store, meta, program=build(), options=opts,
                               crash_batch=3)
    with pytest.raises(_Boom):
        dead.run_stream(StreamSource.from_records(events, batch_records=100),
                        announce=False, flush=False)
    report = _stream(build(), store, opts, events=events, meta=meta)
    assert report.error is None
    got = build().collect_outputs(store)
    assert ref and got == ref                       # no lost windows
    for key in ref:
        assert store.put_counts[key] == 1, key      # no duplicates


# ---------------------------------------------------------------------------
# Plan-level step parity (donation, carry layout, slot reads)
# ---------------------------------------------------------------------------

def _device_rows(rng, n, fanout, n_slots, keymax):
    last = rng.integers(0, 3 * n_slots, n)
    nw = rng.integers(1, fanout + 1, n)
    keys = rng.integers(0, keymax, n)
    vals = rng.integers(0, 100, n)
    valid = rng.random(n) > 0.1
    return np.stack([last, nw, keys, vals, valid], axis=1).astype(np.float32)


@pytest.mark.parametrize("hashed", [False, True], ids=["dense", "hashed"])
def test_step_parity_with_donation_and_slot_ops(hashed):
    """Drive the compiled steps directly: two folds (second with the carry
    donated — in-place via the kernel's input_output_aliases), identical
    carries, stats, and read_slot/top_k_slot views across backends."""
    rng = np.random.default_rng(31)
    n_slots, nb = 8, 16
    ks = KeySpace.hashed(nb, False) if hashed else KeySpace.dense(nb)
    plan = ExecutionPlan(ks, ReduceSpec(mode="top_k", k=3), W,
                         WindowSpec(100.0, 25.0, n_slots))
    cv = plan.compile(backend="vmap")
    cp = plan.compile(backend="pallas")
    keymax = (1 << 20) if hashed else nb
    carry_v, carry_p = cv.init_carry(), cp.init_carry()
    assert carry_v.shape == (W, n_slots * nb // W, 2)
    assert carry_p.shape == (n_slots * nb, 2)       # flat single slab
    for step, donate in ((0, False), (1, True)):
        rows = _device_rows(rng, 400, plan.window.fanout, n_slots, keymax)
        carry_v, sv = cv.step(rows.reshape(W, 100, 5), carry_v, 2,
                              donate=donate)
        carry_p, sp = cp.step(rows, carry_p, 2, donate=donate)
        assert np.array_equal(np.asarray(sv), np.asarray(sp))
        assert np.array_equal(np.asarray(carry_v).reshape(-1, 2),
                              np.asarray(carry_p))
    for slot in range(n_slots):
        assert np.array_equal(cv.read_slot(carry_v, slot),
                              cp.read_slot(carry_p, slot))
    for a, b in zip(cv.top_k_slot(carry_v, 3), cp.top_k_slot(carry_p, 3)):
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# Dispatch validation: shapes without a pallas lowering say so
# ---------------------------------------------------------------------------

def test_pallas_rejects_unsupported_plan_shapes():
    ks, ws = KeySpace.dense(16), WindowSpec(100.0, 25.0, 8)
    with pytest.raises(ValueError, match="group-mode"):
        ExecutionPlan(ks, ReduceSpec(mode="group", capacity=8), W,
                      ws).compile(backend="pallas")
    with pytest.raises(ValueError, match="streaming aggregate fold only"):
        ExecutionPlan(ks, ReduceSpec(), W).compile(
            map_fn=lambda s: None, backend="pallas")
    with pytest.raises(ValueError, match="combine_fn does not apply"):
        ExecutionPlan(ks, ReduceSpec(combine_fn="pallas"), W,
                      ws).compile(backend="pallas")
    with pytest.raises(ValueError, match="unknown backend"):
        ExecutionPlan(ks, ReduceSpec(), W, ws).compile(backend="mosaic")
