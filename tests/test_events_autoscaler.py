"""Event bus (Kafka stand-in) and autoscaler (Knative KPA stand-in)."""

import time

from repro.core.autoscaler import AutoscalerConfig, ServerlessPool
from repro.core.events import CloudEvent, EventBus, trigger_event


def test_produce_poll_roundtrip():
    bus = EventBus()
    ev = trigger_event("mapper", "j1", 0, {"attempt": 0})
    bus.produce("t", ev, key="j1/0")
    recs = bus.poll("g", "t", timeout=0.5)
    assert len(recs) == 1
    assert recs[0].value.data["job_id"] == "j1"


def test_consumer_groups_are_independent():
    bus = EventBus()
    bus.produce("t", CloudEvent("x", "s", {}), key="a")
    assert len(bus.poll("g1", "t", timeout=0.2)) == 1
    assert len(bus.poll("g2", "t", timeout=0.2)) == 1   # own offsets
    assert len(bus.poll("g1", "t", timeout=0.05)) == 0  # consumed


def test_key_partitioning_is_stable():
    bus = EventBus()
    t = bus.create_topic("t", n_partitions=4)
    p1 = t.partition_for("job-1/3")
    p2 = t.partition_for("job-1/3")
    assert p1 == p2


def test_seek_replays_after_failure():
    bus = EventBus()
    for i in range(5):
        bus.produce("t", CloudEvent("x", "s", {"i": i}))
    first = bus.poll("g", "t", timeout=0.2, max_records=10)
    assert len(first) == 5
    bus.seek("g", "t", partition=first[0].partition, offset=0)
    replay = bus.poll("g", "t", timeout=0.2, max_records=10)
    assert [r.value.data["i"] for r in replay if r.partition ==
            first[0].partition] == [r.value.data["i"] for r in first
                                    if r.partition == first[0].partition]


def test_lag_signal():
    bus = EventBus()
    for _ in range(3):
        bus.produce("t", CloudEvent("x", "s", {}))
    assert bus.lag("g", "t") == 3
    bus.poll("g", "t", timeout=0.2, max_records=10)
    assert bus.lag("g", "t") == 0


# -- autoscaler ---------------------------------------------------------------

def test_scale_from_zero_and_cold_start_accounting():
    pool = ServerlessPool("mapper", AutoscalerConfig(cold_start=0.01,
                                                     max_scale=4))
    assert pool.replicas() == 0              # scale-to-zero initial state
    out = pool.submit(lambda x: x * 2, 21)
    assert out == 42
    assert pool.replicas() == 1
    assert pool.cold_starts == 1
    pool.submit(lambda: None)
    assert pool.cold_starts == 1             # warm reuse


def test_kpa_desired_scale():
    pool = ServerlessPool("x", AutoscalerConfig(target_concurrency=2,
                                                max_scale=10, min_scale=0))
    assert pool.desired_scale(0) == 0
    assert pool.desired_scale(1) == 1
    assert pool.desired_scale(7) == 4
    assert pool.desired_scale(100) == 10


def test_scale_to_zero_after_grace():
    pool = ServerlessPool("x", AutoscalerConfig(scale_to_zero_grace=0.02))
    pool.submit(lambda: None)
    assert pool.replicas() == 1
    time.sleep(0.05)
    pool.reap_idle()
    assert pool.replicas() == 0
