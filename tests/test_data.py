"""Data pipeline: tokenizer properties, packed batches, prefetch, and the
vocab-built-by-MapReduce loop."""

from collections import Counter

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic container: seeded-sampling shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (Coordinator, MemoryStore, MetadataStore,
                        make_wordcount_job, read_final_output)
from repro.data import (HashTokenizer, PackedLMDataset, Prefetcher,
                        build_vocab)
from repro.data.tokenizer import fnv1a, preprocess
from repro.data.pipeline import synth_corpus

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


@given(st.text(max_size=200))
def test_preprocess_idempotent_and_clean(text):
    once = preprocess(text)
    assert preprocess(once) == once
    assert "  " not in once
    assert once == once.lower()


@given(st.text(alphabet="abcXYZ ", min_size=1, max_size=50))
def test_hash_tokenizer_stable_and_in_range(text):
    tok = HashTokenizer(512)
    ids = tok.encode(text)
    assert ids == tok.encode(text)
    assert all(0 <= i < 512 for i in ids)


def test_fnv1a_matches_known_vector():
    assert fnv1a("") == 0xCBF29CE484222325


def test_packed_batches_shapes_and_shift():
    store = MemoryStore()
    store.put("input/c.txt", synth_corpus(50_000, seed=3).encode())
    ds = PackedLMDataset(store, "input/", HashTokenizer(1024), batch=4,
                         seq_len=32)
    batch = next(iter(ds))
    assert batch["inputs"].shape == (4, 32)
    assert batch["labels"].shape == (4, 32)
    # next-token alignment within each packed row
    np.testing.assert_array_equal(batch["inputs"][:, 1:],
                                  batch["labels"][:, :-1])


def test_multi_host_shards_are_disjoint_work():
    store = MemoryStore()
    store.put("input/c.txt", synth_corpus(60_000, seed=4).encode())
    tok = HashTokenizer(256)
    rows = []
    for host in range(4):
        ds = PackedLMDataset(store, "input/", tok, batch=2, seq_len=16,
                             host_id=host, n_hosts=4)
        rows.append(np.asarray(next(iter(ds))["inputs"]))
    # different hosts read different byte ranges → different streams
    assert len({r.tobytes() for r in rows}) == 4


def test_prefetcher_preserves_order():
    it = Prefetcher(iter(range(100)), depth=4)
    assert list(it) == list(range(100))


def test_vocab_built_by_mapreduce_job():
    """The paper's pipeline eating its own output: wordcount (MapReduce) →
    vocabulary for the LM data pipeline."""
    corpus = synth_corpus(20_000, vocab_words=50, seed=9)
    store = MemoryStore()
    store.put("input/c.txt", corpus.encode())
    coord = Coordinator(store, MetadataStore())
    cfg = make_wordcount_job(n_mappers=3, n_reducers=2)
    assert coord.run_job(cfg).state.value == "DONE"
    counts = read_final_output(cfg, store)
    vocab = build_vocab(counts, 32)
    assert vocab["<unk>"] == 0 and len(vocab) == 32
    top = Counter(corpus.split()).most_common(5)
    for w, _ in top:
        assert w in vocab
