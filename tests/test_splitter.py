"""Splitter invariants — including the paper's record-boundary extension —
as hypothesis property tests."""

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic container: seeded-sampling shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.splitter import split_object, split_prefix
from repro.core.storage import MemoryStore

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


def _store_with(data: bytes) -> MemoryStore:
    s = MemoryStore()
    s.put("obj", data)
    return s


words = st.lists(st.text(alphabet="abcdef", min_size=1, max_size=8),
                 min_size=1, max_size=300)


@given(words, st.integers(1, 10))
def test_text_split_covers_everything_without_cutting_records(ws, n):
    data = ("\n".join(ws) + "\n").encode()
    store = _store_with(data)
    ranges = split_object(store, "obj", n, binary=False, sep=b"\n")
    # coverage: contiguous, disjoint, complete
    assert ranges[0].lo == 0 and ranges[-1].hi == len(data)
    for a, b in zip(ranges[:-1], ranges[1:]):
        assert a.hi == b.lo
    # record integrity: every range starts at a record boundary
    for r in ranges:
        if r.lo > 0:
            assert data[r.lo - 1:r.lo] == b"\n", "range must start after sep"
    # reassembling the per-range records gives the original records
    rec = []
    for r in ranges:
        rec.extend(data[r.lo:r.hi].decode().split("\n"))
    assert [w for w in rec if w] == ws


@given(st.binary(min_size=1, max_size=5000), st.integers(1, 7))
def test_binary_split_exact_offsets(data, n):
    store = _store_with(data)
    ranges = split_object(store, "obj", n, binary=True)
    assert ranges[0].lo == 0 and ranges[-1].hi == len(data)
    assert b"".join(data[r.lo:r.hi] for r in ranges) == data


@given(st.lists(st.integers(0, 5000), min_size=1, max_size=8),
       st.integers(1, 6))
def test_prefix_split_balances_bytes(sizes, n_mappers):
    store = MemoryStore()
    rng = np.random.default_rng(0)
    total = 0
    for i, size in enumerate(sizes):
        body = bytes(rng.integers(97, 105, size, dtype=np.uint8))
        store.put(f"in/{i}", body)
        total += size
    assignments = split_prefix(store, "in/", n_mappers, binary=True)
    assert len(assignments) == n_mappers
    got = sum(r.size for a in assignments for r in a)
    assert got == total
    # balance: no mapper holds more than ~2× the fair share (greedy bound)
    if total > 0 and n_mappers > 1:
        fair = total / n_mappers
        biggest = max(sum(r.size for r in a) for a in assignments)
        biggest_obj = max(sizes)
        assert biggest <= max(2 * fair, biggest_obj) + 1


def test_long_record_spanning_splits():
    """One record longer than a whole split must not be cut."""
    data = b"short\n" + b"x" * 1000 + b"\nend\n"
    store = _store_with(data)
    ranges = split_object(store, "obj", 5, binary=False)
    rec = []
    for r in ranges:
        rec.extend(data[r.lo:r.hi].split(b"\n"))
    assert b"x" * 1000 in rec
