"""The declarative Pipeline API: graph validation, map fusion, one
definition running batch + streaming with bit-identical windows, session
windows vs a host reference, top-k exactness vs a full sort, windowed join
parity (symmetric and per-side key spaces), multi-stage chains via carry
handoff (reduce → map → window → reduce), the two-node array path, shared
host/device key hashing, and restart write-idempotency."""

import json
from collections import Counter, defaultdict

import numpy as np
import pytest

from repro.core import MemoryStore, MetadataStore
from repro.engine.stages import device_hash, fold_key24, host_bucket
from repro.pipeline import Pipeline, PipelineError, Windowing
from repro.streaming import (SessionTracker, StreamSource,
                             StreamingCoordinator, LateEventError)

W = 4


def _events(n=2000, n_keys=8, span=200.0, seed=0, vmax=20):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.uniform(0, span, n))
    keys = rng.integers(0, n_keys, n)
    vals = rng.integers(0, vmax, n).astype(float)   # ints exact in fp32
    return [(float(t), f"k{k}", float(v))
            for t, k, v in zip(ts, keys, vals)]


def _streamed(built, store):
    built.run_streaming(store, MetadataStore())
    prefix = f"{built.output_prefix.rstrip('/')}/{built.job_id}/"
    return {m.key: store.get(m.key) for m in store.list_objects(prefix)}


def _decoded(outputs):
    return {k.rsplit("/", 1)[1]: [json.loads(ln) for ln in v.splitlines()]
            for k, v in outputs.items()}


# ---------------------------------------------------------------------------
# Graph construction + validation
# ---------------------------------------------------------------------------

def test_graph_is_immutable_and_reusable():
    base = Pipeline.from_source(records=[(0.0, "a", 1.0)]).key_by()
    p1 = base.window(Windowing.tumbling(10.0)).reduce("sum")
    p2 = base.window(Windowing.tumbling(20.0)).reduce("count")
    assert len(base.nodes) == 2 and len(p1.nodes) == 4
    assert p1.nodes != p2.nodes


@pytest.mark.parametrize("make,match", [
    (lambda: Pipeline().reduce("sum"), "from_source"),
    (lambda: Pipeline.from_source().key_by().reduce("sum"), "window"),
    (lambda: Pipeline.from_source().window(10.0).reduce("sum")
        .key_by(), "stage order"),
    (lambda: Pipeline.from_source().window(10.0), "reduce"),
    (lambda: Pipeline.from_source().window(10.0).reduce("median"),
     "aggregate reduce"),
    (lambda: Pipeline.from_source().window(10.0)
        .reduce("max", mode="group"), "capacity"),
    (lambda: Pipeline.from_source().window(Windowing.session(5.0))
        .reduce("sum").top_k(3), "session"),
    (lambda: Pipeline.from_source().window(Windowing.sliding(5.0, 10.0))
        .reduce("sum"), "slide"),
])
def test_malformed_graphs_rejected(make, match):
    with pytest.raises(PipelineError, match=match):
        make().build(num_buckets=16, n_workers=W)


def test_join_sides_must_share_window():
    left = (Pipeline.from_source(records=[(0.0, "a", 1.0)])
            .window(10.0).reduce("sum"))
    right = (Pipeline.from_source(records=[(0.0, "a", 1.0)])
             .window(20.0).reduce("sum"))
    with pytest.raises(PipelineError, match="share one window"):
        left.join(right).build(num_buckets=16, n_workers=W)


def test_adjacent_maps_fuse_into_one_stage():
    """Two maps + a filter fuse into one host transform; the fused chain
    flat-maps, filters, and rewrites records."""
    events = [(float(i), "x", float(i)) for i in range(8)]
    p = (Pipeline.from_source(records=events, batch_records=8)
         .map(lambda r: (r[0], "even" if r[2] % 2 == 0 else "odd", r[2]))
         .map(lambda r: None if r[1] == "odd" else r)
         .map(lambda r: [r, (r[0], r[1], 0.0)])    # flat-map: echo a zero
         .key_by()
         .window(Windowing.tumbling(100.0))
         .reduce("sum"))
    built = p.build(num_buckets=16, n_workers=W, job_id="fuse")
    assert built.sides[0].transform is not None
    out = _decoded(_streamed(built, MemoryStore()))
    assert out == {"window-0.000-100.000": [["even", 0 + 2 + 4 + 6]]}


# ---------------------------------------------------------------------------
# One definition, both modes — bit identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("agg", ["count", "sum", "mean"])
def test_batch_and_streaming_bit_identical(agg):
    events = _events()
    p = (Pipeline.from_source(records=events, batch_records=128)
         .map(lambda r: (r[0], r[1], r[2] + 1.0))
         .key_by()
         .window(Windowing.tumbling(50.0))
         .reduce(agg)
         .sink("out/"))
    built = p.build(num_buckets=16, n_workers=W, job_id=f"bi-{agg}")
    stream_store = MemoryStore()
    streamed = _streamed(built, stream_store)
    batched, report = built.run_batch(MemoryStore())
    assert report.batches == 1
    assert streamed and streamed == batched     # byte-for-byte, every window
    # and both agree with a host oracle
    oracle = defaultdict(lambda: defaultdict(list))
    for ts, k, v in events:
        oracle[int(ts // 50.0)][k].append(v + 1.0)
    got = _decoded(streamed)
    for widx, per_key in oracle.items():
        win = got[f"window-{widx * 50.0:.3f}-{(widx + 1) * 50.0:.3f}"]
        want = {k: {"count": len(vs), "sum": sum(vs),
                    "mean": sum(vs) / len(vs)}[agg]
                for k, vs in per_key.items()}
        assert dict(win) == pytest.approx(want)


def test_expanding_flat_map_runs_in_both_modes():
    """A net-expanding flat-map (2 output records per input) must not
    break either mode: the coordinator grows its wire buffer instead of
    failing, and the modes stay bit-identical."""
    events = [(float(i), "k", 1.0) for i in range(10)]
    p = (Pipeline.from_source(records=events, batch_records=10)
         .map(lambda r: [r, (r[0], "echo", r[2])])
         .key_by()
         .window(Windowing.tumbling(100.0))
         .reduce("count"))
    built = p.build(num_buckets=8, n_workers=W, job_id="expand")
    streamed = _streamed(built, MemoryStore())
    batched, _ = built.run_batch(MemoryStore())
    assert streamed == batched
    assert _decoded(streamed) == {
        "window-0.000-100.000": [["echo", 10], ["k", 10]]}


def test_sliding_pipeline_bit_identical_both_modes():
    events = _events(n=1500, span=150.0, seed=4)
    p = (Pipeline.from_source(records=events, batch_records=100)
         .key_by().window(Windowing.sliding(40.0, 10.0)).reduce("sum"))
    built = p.build(num_buckets=16, n_workers=W, job_id="slide")
    streamed = _streamed(built, MemoryStore())
    batched, _ = built.run_batch(MemoryStore())
    assert streamed and streamed == batched


# ---------------------------------------------------------------------------
# Session windows
# ---------------------------------------------------------------------------

def test_session_tracker_merges_and_finalizes():
    t = SessionTracker(gap=5.0, n_slots=4)
    s0, m = t.admit(1, 0.0)             # session [0, 5)
    assert m == []
    s1, m = t.admit(1, 8.0)             # separate session [8, 13)
    assert s1 != s0 and m == []
    slot, merges = t.admit(1, 2.0)      # extends session 0 → [0, 7)
    assert slot == s0 and merges == []
    # a bridging event ([4, 9) overlaps both) merges into the earlier one
    slot, merges = t.admit(1, 4.0)
    assert slot == s0 and merges == [(s1, s0)]
    assert t.open_sessions == 1
    t.observe(40.0)
    ripe = t.ripe()
    assert len(ripe) == 1 and (ripe[0].start, ripe[0].end) == (0.0, 13.0)
    t.release(ripe[0])
    assert t.open_sessions == 0


def test_session_tracker_cells_shared_across_buckets():
    """Sessions of different keys share ring slots (their cells differ);
    same-key concurrent sessions need distinct slots and overflow raises."""
    t = SessionTracker(gap=1.0, n_slots=2)
    assert t.admit(0, 0.0)[0] == 0
    assert t.admit(1, 0.0)[0] == 0      # other bucket: same slot is fine
    assert t.admit(0, 10.0)[0] == 1     # same bucket: second slot
    with pytest.raises(LateEventError, match="session ring full"):
        t.admit(0, 20.0)                # both cells of bucket 0 occupied


def _session_reference(events, gap, agg="sum"):
    """Host reference: per key, maximal runs of sorted event times with no
    gap > ``gap``; session [min_ts, max_ts + gap) — merged across any
    arrival order."""
    per_key = defaultdict(list)
    for ts, k, v in events:
        per_key[k].append((ts, v))
    out = {}
    for k, tv in per_key.items():
        tv.sort()
        run = [tv[0]]
        for ts, v in tv[1:]:
            if ts - run[-1][0] > gap:
                out[(k, run[0][0], run[-1][0] + gap)] = [x[1] for x in run]
                run = []
            run.append((ts, v))
        out[(k, run[0][0], run[-1][0] + gap)] = [x[1] for x in run]
    if agg == "sum":
        return {key: sum(vs) for key, vs in out.items()}
    if agg == "count":
        return {key: len(vs) for key, vs in out.items()}
    return {key: sum(vs) / len(vs) for key, vs in out.items()}


def test_session_windows_match_host_reference_across_batches():
    """Sessionized traces: bursts per key with real inactivity gaps, mild
    out-of-order arrival (bridging events merge sessions mid-stream), split
    over many micro-batches — assignment and aggregates must match the
    gap-merging host reference, and batch mode must be bit-identical to
    streaming."""
    rng = np.random.default_rng(3)
    events = []
    for k in range(5):
        t = rng.uniform(0, 10.0)
        for _burst in range(6):
            for _ in range(rng.integers(2, 6)):
                events.append((float(t), f"k{k}",
                               float(rng.integers(1, 9))))
                t += float(rng.uniform(0.1, 3.0))   # intra-session spacing
            t += float(rng.uniform(8.0, 30.0))      # inactivity gap > 5
    events.sort()
    # bounded disorder, covered by allowed_lateness below
    events = [(ts + float(j), k, v)
              for (ts, k, v), j in zip(events,
                                       rng.uniform(-1.5, 1.5, len(events)))]
    gap = 5.0
    p = (Pipeline.from_source(records=events, batch_records=32)
         .key_by().window(Windowing.session(gap)).reduce("sum"))
    built = p.build(num_buckets=8, n_workers=W, n_slots=6,
                    allowed_lateness=4.0, job_id="sess")
    streamed = _streamed(built, MemoryStore())
    batched, report = built.run_batch(MemoryStore())
    assert report.error is None and streamed == batched
    want = _session_reference(events, gap)
    got = {}
    for key, blob in streamed.items():
        name = key.rsplit("/", 1)[1]            # session-<key>-<start>-<end>
        _, k, start, end = name.rsplit("-", 3)
        ((label, value),) = [json.loads(ln) for ln in blob.splitlines()]
        assert label == k
        got[(k, round(float(start), 3), round(float(end), 3))] = value
    want = {(k, round(s, 3), round(e, 3)): v for (k, s, e), v in want.items()}
    assert got == pytest.approx(want)


def test_session_windows_checkpoint_resume_bit_identical():
    """A crashed + resumed session stream (open sessions straddling the
    crash) reproduces the uninterrupted run byte for byte."""
    events = _events(n=600, n_keys=4, span=300.0, seed=8)

    def build():
        return (Pipeline.from_source(records=events, batch_records=50)
                .key_by().window(Windowing.session(7.0)).reduce("count")
                .build(num_buckets=8, n_workers=W, n_slots=6,
                       job_id="sessres"))

    ref = _streamed(build(), MemoryStore())
    store, meta = MemoryStore(), MetadataStore()
    built = build()
    built.run_streaming(store, meta,
                        source=StreamSource.from_records(events[:300],
                                                         batch_records=50),
                        flush=False)
    built2 = build()
    built2.run_streaming(store, meta)
    got = {m.key: store.get(m.key)
           for m in store.list_objects("stream-output/sessres/")}
    assert ref and got == ref


# ---------------------------------------------------------------------------
# Top-k / heavy hitters
# ---------------------------------------------------------------------------

def test_top_k_exact_vs_full_sort_closed_domain():
    """On a closed (dense) key domain the fixed-capacity top-k selection
    must equal the head of a full sort of the per-window aggregates —
    streaming and batch, bit-identically."""
    events = _events(n=3000, n_keys=12, span=100.0, seed=5)
    k = 4
    p = (Pipeline.from_source(records=events, batch_records=200)
         .key_by().window(Windowing.tumbling(25.0))
         .reduce("count").top_k(k))
    built = p.build(num_buckets=16, n_workers=W, job_id="topk")
    streamed = _streamed(built, MemoryStore())
    batched, _ = built.run_batch(MemoryStore())
    assert streamed and streamed == batched
    oracle = defaultdict(Counter)
    for ts, key, _v in events:
        oracle[int(ts // 25.0)][key] += 1
    got = _decoded(streamed)
    assert len(got) == len(oracle)
    for widx, counts in oracle.items():
        rows = got[f"window-{widx * 25.0:.3f}-{(widx + 1) * 25.0:.3f}"]
        assert len(rows) == k
        full_sort = sorted(counts.values(), reverse=True)
        assert [v for _k, v in rows] == full_sort[:k]   # exact, rank order
        for key, v in rows:
            assert counts[key] == v                     # keys truly heavy


def test_top_k_batch_array_pipeline():
    """top_k as a graph node on an array (device UDF) pipeline: the batch
    plan returns the k heaviest buckets of the aggregate vector."""
    import jax.numpy as jnp

    def map_fn(shard):
        keys = shard[:, 0].astype(jnp.int32)
        return keys, shard[:, 1], shard[:, 2] > 0

    rows = np.zeros((W, 8, 3), np.float32)
    weights = {3: 50.0, 7: 30.0, 1: 20.0, 5: 10.0}
    i = 0
    for key, total in weights.items():
        for _ in range(2):
            rows[i % W, i // W] = (key, total / 2, 1.0)
            i += 1
    p = (Pipeline.from_source(shards=rows).map(map_fn)
         .reduce("sum").top_k(3))
    built = p.build(num_buckets=8, n_workers=W)
    (ids, vals, valid), _stats = built.run_batch(data=rows)
    assert ids[valid].tolist() == [3, 7, 1]
    assert vals[valid].tolist() == [50.0, 30.0, 20.0]


# ---------------------------------------------------------------------------
# Windowed joins
# ---------------------------------------------------------------------------

def test_windowed_join_parity_and_oracle():
    rng = np.random.default_rng(11)
    mk = lambda n, seed: _events(n=n, n_keys=6, span=100.0, seed=seed,
                                 vmax=9)
    left_ev, right_ev = mk(800, 12), mk(500, 13)
    left = (Pipeline.from_source(records=left_ev, batch_records=100)
            .key_by().window(Windowing.tumbling(25.0)).reduce("sum"))
    right = (Pipeline.from_source(records=right_ev, batch_records=100)
             .key_by().window(Windowing.tumbling(25.0)).reduce("count"))
    built = left.join(right).build(num_buckets=12, n_workers=W,
                                   job_id="join")
    streamed = _streamed(built, MemoryStore())
    batched, _ = built.run_batch(MemoryStore())
    assert streamed and streamed == batched         # parity, byte for byte
    lsum = defaultdict(lambda: defaultdict(float))
    rcnt = defaultdict(lambda: defaultdict(int))
    for ts, k, v in left_ev:
        lsum[int(ts // 25.0)][k] += v
    for ts, k, _v in right_ev:
        rcnt[int(ts // 25.0)][k] += 1
    got = _decoded(streamed)
    for widx in lsum:
        rows = dict(got[f"window-{widx * 25.0:.3f}-{(widx + 1) * 25.0:.3f}"])
        want = {k: [lsum[widx][k], rcnt[widx][k]]
                for k in lsum[widx] if rcnt[widx].get(k)}
        assert rows == pytest.approx(want)          # inner join, both aggs


def test_join_per_side_num_buckets_parity_and_oracle():
    """num_buckets=(left, right) sizes the two key spaces independently:
    the symmetric tuple must be byte-identical to the int path, and the
    asymmetric build must produce the same joined content (and survive the
    streaming drive) — the carry widens to the larger side while each
    side's dictionary stays within its own declared space."""
    mk = lambda n, n_keys, seed: _events(n=n, n_keys=n_keys, span=100.0,
                                         seed=seed, vmax=9)
    left_ev, right_ev = mk(600, 4, 14), mk(900, 20, 15)
    left = (Pipeline.from_source(records=left_ev, batch_records=100)
            .key_by().window(Windowing.tumbling(25.0)).reduce("sum"))
    right = (Pipeline.from_source(records=right_ev, batch_records=100)
             .key_by().window(Windowing.tumbling(25.0)).reduce("count"))
    sym_t, _ = left.join(right).build(num_buckets=(20, 20), n_workers=W,
                                      job_id="jsym").run_batch(MemoryStore())
    sym_i, _ = left.join(right).build(num_buckets=20, n_workers=W,
                                      job_id="jsym").run_batch(MemoryStore())
    assert sym_t and sym_t == sym_i        # tuple(L, L) ≡ int L, byte for byte
    asym = left.join(right).build(num_buckets=(4, 20), n_workers=W,
                                  job_id="jasym")
    assert [s.num_buckets for s in asym.sides] == [4, 20]
    assert asym.num_buckets == 20          # the shared carry takes the max
    batched, _ = asym.run_batch(MemoryStore())
    strip = lambda outs: {k.rsplit("/", 1)[1]: v for k, v in outs.items()}
    assert strip(batched) == strip(sym_i)  # same joined content
    streamed = _streamed(asym, MemoryStore())
    assert strip(streamed) == strip(batched)    # and both modes agree


def test_join_per_side_num_buckets_validation():
    left = (Pipeline.from_source(records=[(0.0, "a", 1.0)])
            .window(10.0).reduce("sum"))
    right = (Pipeline.from_source(records=[(0.0, "a", 1.0)])
             .window(10.0).reduce("count"))
    with pytest.raises(PipelineError, match="only applies to joins"):
        left.build(num_buckets=(8, 16), n_workers=W)
    with pytest.raises(PipelineError, match="hashed joins"):
        left.join(right).build(num_buckets=(8, 16), n_workers=W,
                               key_space="hashed")
    with pytest.raises(PipelineError, match="pair"):
        left.join(right).build(num_buckets=(8, 16, 32), n_workers=W)


def test_join_on_key_extractor():
    """join(on=...) overrides both sides' keys."""
    left = [(1.0, ("user", 7), 5.0)]
    right = [(2.0, ("user", 7), 1.0)]
    lp = (Pipeline.from_source(records=left).window(10.0).reduce("sum"))
    rp = (Pipeline.from_source(records=right).window(10.0).reduce("count"))
    built = lp.join(rp, on=lambda r: r[1][1]).build(num_buckets=8,
                                                    n_workers=W,
                                                    job_id="jon")
    outs, _ = built.run_batch(MemoryStore())
    assert _decoded(outs) == {"window-0.000-10.000": [["7", [5.0, 1]]]}


# ---------------------------------------------------------------------------
# Multi-stage chains: reduce → map → window → reduce via carry handoff
# ---------------------------------------------------------------------------

def _two_phase_oracle(events, w1, w2):
    """Host reference for count-per-w1-window → per-key sum over w2."""
    c1 = defaultdict(Counter)
    for ts, k, _v in events:
        c1[int(ts // w1)][k] += 1
    c2 = defaultdict(Counter)
    for idx, counts in c1.items():
        for k, c in counts.items():
            c2[int((idx * w1) // w2)][k] += c
    return c2


def test_multistage_graph_bit_identical_both_modes():
    """The acceptance graph — map → key_by → window → reduce → map →
    key_by → window → reduce — runs in batch and streaming with
    bit-identical per-window bytes, and matches a two-phase host oracle.
    The inter-stage map forces the host handoff path (records
    materialize); the values stay exact in float32."""
    events = _events(n=2500, n_keys=6, span=200.0, seed=20)
    p = (Pipeline.from_source(records=events, batch_records=200)
         .map(lambda r: (r[0], r[1], 1.0))
         .key_by()
         .window(Windowing.tumbling(10.0))
         .reduce("count")
         .map(lambda r: (r[0], r[1].upper(), r[2]))   # host boundary
         .key_by()
         .window(Windowing.tumbling(50.0))
         .reduce("sum")
         .sink("two-phase/"))
    built = p.build(num_buckets=12, n_workers=W, job_id="ms-accept")
    assert built.is_multistage and len(built.stages) == 2
    assert not built.stages[0].handoff_device    # the map needs the host
    streamed = _streamed(built, MemoryStore())
    batched, report = built.run_batch(MemoryStore())
    assert streamed and streamed == batched      # byte for byte
    assert report.handoffs > 0 and report.error is None
    oracle = _two_phase_oracle(events, 10.0, 50.0)
    got = _decoded(streamed)
    assert len(got) == len(oracle)
    for widx, counts in oracle.items():
        win = got[f"window-{widx * 50.0:.3f}-{(widx + 1) * 50.0:.3f}"]
        assert dict(win) == {k.upper(): v for k, v in counts.items()}


def test_multistage_handoff_transport_agrees_on_topk_ties():
    """Regression: the two handoff transports must assign the *same*
    downstream key ids (eager registration in first-seen order on identity
    boundaries), or a final-stage top_k breaks ties toward different
    buckets.  'z' arrives before 'a' with equal mass — both transports
    must crown 'z'."""
    events = [(float(i), k, 1.0)
              for i in range(8) for k in ("z", "a")]   # tied counts, z first
    p = (Pipeline.from_source(records=events, batch_records=4)
         .key_by().window(Windowing.tumbling(2.0)).reduce("count")
         .window(Windowing.tumbling(8.0)).reduce("sum").top_k(1))
    outs = {}
    for handoff in ("device", "host"):
        built = p.build(num_buckets=8, n_workers=W, job_id="tie",
                        handoff=handoff)
        outs[handoff], _ = built.run_batch(MemoryStore())
    assert outs["device"] == outs["host"]
    for rows in _decoded(outs["device"]).values():
        assert rows == [["z", 8.0]]     # first seen wins the tie, both paths


def test_multistage_device_handoff_equals_host_handoff():
    """A boundary with no host transform lowers to the on-device handoff;
    forcing handoff='host' must produce byte-identical windows — the
    device op is an optimization, not a semantics change."""
    events = _events(n=2000, n_keys=8, span=160.0, seed=21)
    p = (Pipeline.from_source(records=events, batch_records=250)
         .key_by().window(Windowing.tumbling(8.0)).reduce("count")
         .window(Windowing.tumbling(40.0)).reduce("sum").top_k(3))
    dev = p.build(num_buckets=16, n_workers=W, job_id="msh")
    host = p.build(num_buckets=16, n_workers=W, job_id="msh",
                   handoff="host")
    assert dev.stages[0].handoff_device and not host.stages[0].handoff_device
    out_dev, _ = dev.run_batch(MemoryStore())
    out_host, _ = host.run_batch(MemoryStore())
    assert out_dev and out_dev == out_host
    # and the streaming drive of the device path agrees too
    assert _streamed(dev, MemoryStore()) == out_dev


@pytest.mark.slow
def test_multistage_streaming_parity_with_sliding_second_stage():
    """Sliding windows in the second stage: each finalized first-stage
    window fans into several second-stage windows on device; batch and
    streaming must stay bit-identical and conserve the total count."""
    events = _events(n=3000, n_keys=5, span=300.0, seed=22)
    p = (Pipeline.from_source(records=events, batch_records=150)
         .key_by().window(Windowing.tumbling(10.0)).reduce("count")
         .window(Windowing.sliding(60.0, 20.0)).reduce("sum"))
    built = p.build(num_buckets=20, n_workers=W, job_id="ms-slide")
    assert built.stages[0].handoff_device
    streamed = _streamed(built, MemoryStore())
    batched, _ = built.run_batch(MemoryStore())
    assert streamed and streamed == batched
    got = _decoded(streamed)
    # every 10s window start lands in 3 sliding [start, start+60) windows
    # (slide 20): conservation → total mass = 3 × record count
    total = sum(v for rows in got.values() for _k, v in rows)
    assert total == 3 * len(events)


@pytest.mark.slow
def test_multistage_crash_restore_no_duplicate_or_lost_windows():
    """A mid-stream crash + restore of a two-stage graph: the resumed run
    reproduces the uninterrupted run byte for byte, every second-stage
    window object is written exactly once across the crash, and none are
    lost — the checkpoint snapshots all carries as one pytree."""
    events = _events(n=2000, n_keys=5, span=400.0, seed=23)

    def build(handoff):
        return (Pipeline.from_source(records=events, batch_records=100)
                .key_by().window(Windowing.tumbling(10.0)).reduce("count")
                .window(Windowing.tumbling(50.0)).reduce("sum")
                .build(num_buckets=12, n_workers=W, checkpoint_interval=4,
                       job_id="ms-res", handoff=handoff))

    for handoff in ("device", "host"):
        ref = _streamed(build(handoff), MemoryStore())
        store, meta = CountingStore(), MetadataStore()
        build(handoff).run_streaming(
            store, meta, flush=False,
            source=StreamSource.from_records(events[:1100],
                                             batch_records=100))
        assert set(store.put_counts) & set(ref)    # windows landed pre-crash
        report = build(handoff).run_streaming(store, meta)
        assert report.error is None
        got = {m.key: store.get(m.key)
               for m in store.list_objects("stream-output/ms-res/")}
        assert got == ref                          # no lost windows
        for key in ref:
            assert store.put_counts[key] == 1, (handoff, key)  # no dupes


@pytest.mark.slow
def test_multistage_shard_map_matches_vmap():
    """The handoff keeps the flat global wire layout under shard_map:
    a two-stage graph over a real mesh axis must emit byte-identical
    windows to the vmap drive."""
    import os
    import subprocess
    import sys
    code = """
import jax, numpy as np
from repro.core import MemoryStore, MetadataStore
from repro.pipeline import Pipeline, Windowing
mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("workers",))
events = [(float(t), f"k{t % 5}", float(t % 7)) for t in range(600)]
p = (Pipeline.from_source(records=events, batch_records=100)
     .key_by().window(Windowing.tumbling(20.0)).reduce("count")
     .window(Windowing.tumbling(100.0)).reduce("sum"))
outs = []
for backend, m in (("vmap", None), ("shard_map", mesh)):
    built = p.build(num_buckets=20, n_workers=4, job_id="sm2",
                    backend=backend, mesh=m)
    assert built.stages[0].handoff_device
    store = MemoryStore()
    built.run_streaming(store, MetadataStore())
    outs.append({x.key: store.get(x.key)
                 for x in store.list_objects("stream-output/sm2/")})
assert outs[0] and outs[0] == outs[1]
print("OK")
"""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    proc = subprocess.run([sys.executable, "-c", code],
                          cwd=os.path.dirname(os.path.dirname(__file__)),
                          env={**os.environ, **env},
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_multistage_validation():
    base = (Pipeline.from_source(records=[(0.0, "a", 1.0)])
            .key_by().window(10.0).reduce("count"))
    # an intermediate session stage would finalize out of start order
    with pytest.raises(PipelineError, match="session"):
        (Pipeline.from_source(records=[(0.0, "a", 1.0)])
         .key_by().window(Windowing.session(5.0)).reduce("sum")
         .window(10.0).reduce("sum")).build(num_buckets=8, n_workers=W)
    # a join may take multi-stage inputs, but the chain cannot continue
    # past it (rank the join output in a downstream pipeline instead)
    right = (Pipeline.from_source(records=[(0.0, "a", 1.0)])
             .window(10.0).reduce("sum"))
    with pytest.raises(PipelineError, match="past a join"):
        (base.window(10.0).reduce("sum").join(right).window(10.0)
         .reduce("sum")).build(num_buckets=8, n_workers=W)
    # a join over a multi-stage left side lowers (the lifted restriction)
    built = (base.window(10.0).reduce("sum").join(right)
             ).build(num_buckets=8, n_workers=W)
    assert len(built.stages) == 2 and built.stages[1].is_join
    assert built.edges and built.edges[0].dst_side == 0
    # an unfinished trailing stage is rejected with the grammar hint
    with pytest.raises(PipelineError, match="stage 2"):
        base.key_by().build(num_buckets=8, n_workers=W)


# ---------------------------------------------------------------------------
# Shared host/device hashing (no drift possible)
# ---------------------------------------------------------------------------

def test_host_bucket_mirrors_device_hash():
    import jax.numpy as jnp
    rng = np.random.default_rng(2)
    raws = rng.integers(0, 1 << 24, 500)
    for nb in (7, 16, 37, 128):
        dev = np.asarray(device_hash(jnp.asarray(raws, jnp.int32))
                         % np.uint32(nb)).astype(int)
        assert [host_bucket(int(r), nb) for r in raws] == dev.tolist()


def test_fold_key24_fits_wire_and_is_stable():
    ids = {fold_key24(k) for k in (f"key-{i}" for i in range(200))}
    assert all(0 <= r < (1 << 24) for r in ids)
    assert fold_key24("abc") == fold_key24("abc")
    assert len(ids) > 190       # 24-bit fold rarely collides at n=200


# ---------------------------------------------------------------------------
# Two-node array path: the batch mode the removed mapreduce() shim wrapped
# ---------------------------------------------------------------------------

def test_two_node_array_pipeline_matches_host_reference():
    """``from_source(shards=).map().reduce()`` — the explicit spelling of
    the removed ``mapreduce()`` shim — agrees with a host-side bincount,
    whether driven through ``run_batch`` or ``run``'s array dispatch."""
    import jax.numpy as jnp

    def map_fn(shard):
        keys = shard[:, 0].astype(jnp.int32)
        return keys, shard[:, 1], shard[:, 2] > 0

    rng = np.random.default_rng(9)
    rows = np.zeros((W, 16, 3), np.float32)
    rows[:, :, 0] = rng.integers(0, 8, (W, 16))
    rows[:, :, 1] = rng.integers(0, 9, (W, 16))
    rows[:, :, 2] = 1.0
    built = (Pipeline.from_source(shards=rows).map(map_fn).reduce("sum")
             .build(num_buckets=8, n_workers=W))
    direct, _stats = built.run_batch(data=rows)
    expected = np.bincount(rows[:, :, 0].astype(int).ravel(),
                           weights=rows[:, :, 1].ravel(), minlength=8)
    np.testing.assert_allclose(np.asarray(direct), expected)
    via_run, _stats2 = built.run(rows)
    assert np.array_equal(np.asarray(via_run), np.asarray(direct))


# ---------------------------------------------------------------------------
# shard_map backend: the same program over a real mesh axis
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_pipeline_streaming_shard_map_matches_vmap():
    """The coordinator ships shard_map-backed programs the flat global
    wire (not the vmap-batched layout); outputs must be byte-identical to
    the vmap drive of the same pipeline."""
    import subprocess
    import sys
    code = """
import jax, numpy as np
from repro.core import MemoryStore, MetadataStore
from repro.pipeline import Pipeline, Windowing
mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("workers",))
events = [(float(t), f"k{t % 5}", float(t % 7)) for t in range(400)]
p = (Pipeline.from_source(records=events, batch_records=100)
     .key_by().window(Windowing.tumbling(50.0)).reduce("sum"))
outs = []
for backend, m in (("vmap", None), ("shard_map", mesh)):
    built = p.build(num_buckets=20, n_workers=4, job_id="sm",
                    backend=backend, mesh=m)
    store = MemoryStore()
    built.run_streaming(store, MetadataStore())
    outs.append({x.key: store.get(x.key)
                 for x in store.list_objects("stream-output/sm/")})
assert outs[0] and outs[0] == outs[1]
print("OK")
"""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    import os
    proc = subprocess.run([sys.executable, "-c", code],
                          cwd=os.path.dirname(os.path.dirname(__file__)),
                          env={**os.environ, **env},
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_key_space_instance_passes_through_verbatim():
    """build(key_space=KeySpace(...)) hands the instance to the plans
    unchanged — callers keep control of collision tracking."""
    from repro.engine import KeySpace

    def map_fn(shard):
        import jax.numpy as jnp
        return shard[:, 0].astype(jnp.int32), shard[:, 1], shard[:, 2] > 0

    ks = KeySpace.hashed(32, track_collisions=False)
    built = (Pipeline.from_source(shards=np.zeros((W, 4, 3), np.float32))
             .map(map_fn).reduce("sum")
             .build(num_buckets=8, n_workers=W, key_space=ks))
    assert built.batch_plan.plan.key_space is ks
    assert built.num_buckets == 32 and built.key_space == "hashed"


# ---------------------------------------------------------------------------
# Restart idempotency: a crash after emission does not re-write windows
# ---------------------------------------------------------------------------

class CountingStore(MemoryStore):
    def __init__(self):
        super().__init__()
        self.put_counts = Counter()

    def put(self, key, data):
        self.put_counts[key] += 1
        return super().put(key, data)


def test_crash_after_emission_resumes_with_single_write():
    """checkpoint_interval > 1 leaves emitted windows ahead of the last
    checkpoint; the resumed run replays those batches but must *skip*
    re-writing the already-persisted windows (byte-identical content), so
    every window object is written exactly once across the crash."""
    events = _events(n=1000, seed=7)

    def build():
        return (Pipeline.from_source(records=events, batch_records=100)
                .key_by().window(Windowing.tumbling(20.0)).reduce("sum")
                .build(num_buckets=16, n_workers=W,
                       checkpoint_interval=4, job_id="once"))

    ref = _streamed(build(), MemoryStore())

    store, meta = CountingStore(), MetadataStore()
    build().run_streaming(
        store, meta, flush=False,
        source=StreamSource.from_records(events[:700], batch_records=100))
    emitted_before_crash = set(store.put_counts) & set(ref)
    assert emitted_before_crash                 # windows landed pre-crash
    report = build().run_streaming(store, meta)
    assert report.batches == 6                  # replay from checkpoint @400
    assert report.writes_skipped > 0
    got = {m.key: store.get(m.key)
           for m in store.list_objects("stream-output/once/")}
    assert got == ref
    for key in ref:
        assert store.put_counts[key] == 1, key  # exactly one write each


def test_crash_before_first_checkpoint_still_single_write():
    """A crash after emissions but before the FIRST checkpoint replays the
    whole log; the already-persisted windows must still not be re-written
    (the restore consults the output prefix even with no checkpoint)."""
    events = _events(n=400, seed=10)

    def build():
        return (Pipeline.from_source(records=events, batch_records=100)
                .key_by().window(Windowing.tumbling(20.0)).reduce("sum")
                .build(num_buckets=16, n_workers=W,
                       checkpoint_interval=50, job_id="first"))

    ref = _streamed(build(), MemoryStore())
    store, meta = CountingStore(), MetadataStore()
    build().run_streaming(
        store, meta, flush=False,
        source=StreamSource.from_records(events[:200], batch_records=100))
    assert set(store.put_counts) & set(ref)     # emissions landed pre-crash
    report = build().run_streaming(store, meta)
    assert report.writes_skipped > 0
    got = {m.key: store.get(m.key)
           for m in store.list_objects("stream-output/first/")}
    assert got == ref
    for key in ref:
        assert store.put_counts[key] == 1, key
