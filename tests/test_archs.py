"""Per-architecture smoke tests (reduced configs, CPU): one forward + one
train step + decode/prefill consistency, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (decode_step, forward, init_cache, init_params,
                          prefill_forward)
from repro.optim import AdamW
from repro.runtime.train_step import init_train_state, make_train_step

B, S = 2, 16
KEY = jax.random.PRNGKey(0)

# per-arch forward/train/decode sweeps are the bulk of the suite's runtime;
# the fast CI gate skips them, the non-blocking slow job runs them
pytestmark = pytest.mark.slow


def _inputs(cfg, key=KEY, b=B, s=S):
    if cfg.input_mode == "embeddings":
        return jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (b, s), 0, cfg.vocab)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = configs.get_reduced(arch)
    params = init_params(KEY, cfg)
    logits, aux = forward(params, _inputs(cfg), cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_one_train_step(arch):
    cfg = configs.get_reduced(arch)
    opt = AdamW(lr=1e-3)
    state = init_train_state(KEY, cfg, opt)
    batch = {"inputs": _inputs(cfg),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    step = jax.jit(make_train_step(cfg, opt))
    new_state, metrics = step(state, batch)
    assert int(new_state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                         state.params, new_state.params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["gemma2-9b", "mixtral-8x7b", "zamba2-1.2b",
                                  "falcon-mamba-7b", "qwen3-32b"])
def test_decode_matches_forward(arch):
    cfg = configs.get_reduced(arch)
    if cfg.is_moe:
        cfg = cfg.replace(capacity_factor=8.0)   # no drops → exact
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    logits_par, _ = forward(params, toks, cfg)
    cache = init_cache(cfg, B, S + 4)
    outs = []
    for t_ in range(S):
        lg, cache = decode_step(params, cache, toks[:, t_:t_ + 1], cfg)
        outs.append(lg)
    err = float(jnp.max(jnp.abs(logits_par - jnp.stack(outs, 1))))
    assert err < 2e-3, err


@pytest.mark.parametrize("arch", ["yi-34b", "zamba2-1.2b", "falcon-mamba-7b"])
def test_prefill_then_decode(arch):
    cfg = configs.get_reduced(arch)
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    logits_par, _ = forward(params, toks, cfg)
    last, cache = prefill_forward(params, toks[:, :S - 1], cfg, S + 4)
    np.testing.assert_allclose(last, logits_par[:, S - 2], rtol=1e-3,
                               atol=2e-3)
    lg, _ = decode_step(params, cache, toks[:, S - 1:S], cfg)
    np.testing.assert_allclose(lg, logits_par[:, -1], rtol=1e-3, atol=2e-3)


def test_moe_capacity_drops_degrade_gracefully():
    cfg = configs.get_reduced("mixtral-8x7b").replace(capacity_factor=0.5)
    params = init_params(KEY, cfg)
    logits, aux = forward(params, _inputs(cfg), cfg)
    assert bool(jnp.all(jnp.isfinite(logits)))   # drops zero-fill, no NaN


def test_gemma2_window_schedule_alternates():
    from repro.models.attention import window_schedule
    cfg = configs.get_reduced("gemma2-9b")
    ws = np.asarray(window_schedule(cfg))
    assert ws[0] > 0 and ws[1] == 0 and ws[2] > 0


def test_long_500k_eligibility():
    from repro.models import shapes_for
    runs_long = {a for a in configs.ARCHS
                 if any(s.name == "long_500k"
                        for s in shapes_for(configs.get(a)))}
    assert runs_long == {"gemma2-9b", "mixtral-8x7b", "zamba2-1.2b",
                         "falcon-mamba-7b"}


def test_param_counts_near_nameplate():
    """Full configs should land near their nameplate sizes."""
    expect = {"gemma2-9b": (8.5e9, 11e9), "yi-34b": (33e9, 36e9),
              "mixtral-8x7b": (44e9, 49e9), "falcon-mamba-7b": (6.5e9, 8e9),
              "qwen3-32b": (31e9, 34.5e9)}
    for arch, (lo, hi) in expect.items():
        n = configs.get(arch).n_params()
        assert lo <= n <= hi, (arch, n)
