"""DAG fan-out: one stage feeding several downstream stages.

Covers the tee grammar (``Pipeline.tee`` + ``Pipeline.branch``), per-edge
handoff transports (device vs host on sibling edges of one teed stage),
batch ↔ streaming bit-identity on every branch, exactly-once crash/restore
across the fan-out, the property that a mid-stream restore rebuilds every
edge's bucket → next-key table bit-identically to an uninterrupted run,
joins over multi-stage inputs, and stage-local build options
(``reduce(..., num_buckets=, n_slots=)``) resolved per ``StagePlan``.
"""

import json
from collections import Counter, defaultdict
from functools import lru_cache

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                 # hermetic container
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import MemoryStore, MetadataStore
from repro.pipeline import Pipeline, PipelineError, Windowing
from repro.streaming import StreamSource, StreamingCoordinator

W = 4
_PROPERTY_SETTINGS = settings(max_examples=5, deadline=None)


def _events(n=1500, n_keys=6, span=200.0, seed=0, vmax=9):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.uniform(0, span, n))
    keys = rng.integers(0, n_keys, n)
    vals = rng.integers(0, vmax, n).astype(float)   # ints exact in fp32
    return [(float(t), f"k{k}", float(v)) for t, k, v in zip(ts, keys, vals)]


def _outputs(built, store):
    """Every terminal branch's emitted windows, keyed by object key."""
    return built.collect_outputs(store)


def _streamed(built, store):
    built.run_streaming(store, MetadataStore())
    return _outputs(built, store)


def _decoded(outputs):
    return {k.rsplit("/", 1)[1] + "@" + k.split("/", 1)[0]:
            [json.loads(ln) for ln in v.splitlines()]
            for k, v in outputs.items()}


def _region(rec):
    ts, key, value = rec
    return ts, ("even" if int(key[1:]) % 2 == 0 else "odd"), value


def _tee_pipeline(events, *, batch_records=150):
    """The acceptance graph: per-key counts per 10 s, teed into a top-k
    branch (identity boundary → device edge) and a per-region rollup
    branch (host transform → host edge)."""
    base = (Pipeline.from_source(records=events, batch_records=batch_records)
            .key_by().window(Windowing.tumbling(10.0)).reduce("count"))
    return base.tee(
        Pipeline.branch().window(Windowing.tumbling(50.0)).reduce("sum")
                .top_k(3).sink("fan-top/"),
        Pipeline.branch().map(_region).key_by()
                .window(Windowing.tumbling(50.0)).reduce("sum")
                .sink("fan-region/"))


# ---------------------------------------------------------------------------
# Tee: parity, per-edge transports, oracles
# ---------------------------------------------------------------------------

def test_tee_two_branch_parity_and_oracle():
    """The acceptance criterion: a tee'd two-branch pipeline (shared
    upstream reduce → top-k branch + rollup branch) produces bit-identical
    window bytes in batch and streaming modes, and each branch matches a
    host oracle."""
    events = _events(n=2000, seed=31)
    built = _tee_pipeline(events).build(num_buckets=12, n_workers=W,
                                        job_id="fan")
    assert len(built.stages) == 3 and built.final_stages == (1, 2)
    streamed = _streamed(built, MemoryStore())
    batched, report = built.run_batch(MemoryStore())
    assert streamed and streamed == batched         # byte for byte, both sinks
    assert report.error is None and report.handoffs > 0
    assert {k.split("/", 1)[0] for k in streamed} == {"fan-top", "fan-region"}

    counts = defaultdict(Counter)                   # host oracle per branch
    for ts, k, _v in events:
        counts[int(ts // 50.0)][k] += 1
    got = _decoded(streamed)
    for widx, per_key in counts.items():
        name = f"window-{widx * 50.0:.3f}-{(widx + 1) * 50.0:.3f}"
        top = got[name + "@fan-top"]
        full_sort = sorted(per_key.values(), reverse=True)
        assert [v for _k, v in top] == full_sort[:3]
        for key, v in top:
            assert per_key[key] == v
        region = dict(got[name + "@fan-region"])
        want = Counter()
        for k, c in per_key.items():
            want["even" if int(k[1:]) % 2 == 0 else "odd"] += c
        assert region == dict(want)


def test_tee_edges_pick_their_own_transport():
    """Sibling edges of one teed stage choose transports independently —
    the identity branch hands off on device while the mapped branch takes
    the host record path — and forcing everything onto the host produces
    byte-identical windows (the device op is an optimization, not a
    semantics change)."""
    events = _events(n=1200, seed=32)
    pipe = _tee_pipeline(events)
    dev = pipe.build(num_buckets=12, n_workers=W, job_id="fan-t")
    transports = {(e.dst_side, e.dst): (e.device, e.eager) for e in dev.edges}
    assert len(dev.edges) == 2
    assert transports[(0, 1)] == (True, True)       # identity → device, eager
    assert transports[(0, 2)] == (False, False)     # mapped → host
    assert not dev.stages[0].handoff_device         # mixed edges: stage view
    host = pipe.build(num_buckets=12, n_workers=W, job_id="fan-t",
                      handoff="host")
    assert not any(e.device for e in host.edges)
    out_dev, _ = dev.run_batch(MemoryStore())
    out_host, _ = host.run_batch(MemoryStore())
    assert out_dev and out_dev == out_host


def test_tee_hashed_key_space_falls_back_to_host_edges():
    """Open (hashed) key domains cannot relabel densely on device, so
    every tee edge takes the host record path (handed-off labels may be
    collision-merged bucket names) — and both modes still agree byte for
    byte on both branches."""
    events = _events(n=800, seed=35)
    base = (Pipeline.from_source(records=events, batch_records=150)
            .key_by().window(Windowing.tumbling(10.0)).reduce("count"))
    built = base.tee(
        Pipeline.branch().window(Windowing.tumbling(50.0)).reduce("sum")
                .top_k(3).sink("fanh-top/"),
        Pipeline.branch().window(Windowing.tumbling(100.0)).reduce("sum")
                .sink("fanh-roll/"),
    ).build(num_buckets=16, n_workers=W, key_space="hashed", job_id="fan-h")
    assert not any(e.device for e in built.edges)
    streamed = _streamed(built, MemoryStore())
    batched, _ = built.run_batch(MemoryStore())
    assert streamed and streamed == batched


def test_nested_tee_three_sinks():
    """A branch may tee again: the DAG nests, every terminal sink stays
    distinct, and both modes agree on all three output streams."""
    events = _events(n=1000, seed=33)
    base = (Pipeline.from_source(records=events, batch_records=200)
            .key_by().window(Windowing.tumbling(10.0)).reduce("count"))
    inner = (Pipeline.branch().window(Windowing.tumbling(40.0)).reduce("sum")
             .tee(Pipeline.branch().window(Windowing.tumbling(200.0))
                  .reduce("sum").sink("nest-a/"),
                  Pipeline.branch().window(Windowing.tumbling(200.0))
                  .reduce("mean").sink("nest-b/")))
    built = base.tee(
        inner,
        Pipeline.branch().window(Windowing.tumbling(40.0)).reduce("sum")
                .top_k(2).sink("nest-c/"),
    ).build(num_buckets=8, n_workers=W, job_id="nest")
    assert len(built.stages) == 5 and len(built.final_stages) == 3
    streamed = _streamed(built, MemoryStore())
    batched, _ = built.run_batch(MemoryStore())
    assert streamed and streamed == batched
    assert {k.split("/", 1)[0] for k in streamed} == \
        {"nest-a", "nest-b", "nest-c"}


# ---------------------------------------------------------------------------
# Crash / restore across the fan-out
# ---------------------------------------------------------------------------

class CountingStore(MemoryStore):
    def __init__(self):
        super().__init__()
        self.put_counts = Counter()

    def put(self, key, data):
        self.put_counts[key] += 1
        return super().put(key, data)


def test_tee_crash_restore_no_lost_or_duplicate_windows():
    """A mid-stream crash + restore of a tee'd graph: the resumed run
    reproduces the uninterrupted run byte for byte on *both* branches,
    every window object is written exactly once across the crash, and
    none are lost — the checkpoint snapshots every stage's carry (branches
    included) as one pytree plus every edge's key table."""
    events = _events(n=1600, n_keys=5, span=320.0, seed=34)

    def build():
        return _tee_pipeline(events, batch_records=100).build(
            num_buckets=12, n_workers=W, checkpoint_interval=4,
            job_id="fan-res")

    ref = _streamed(build(), MemoryStore())
    store, meta = CountingStore(), MetadataStore()
    build().run_streaming(
        store, meta, flush=False,
        source=StreamSource.from_records(events[:900], batch_records=100))
    assert set(store.put_counts) & set(ref)         # windows landed pre-crash
    report = build().run_streaming(store, meta)
    assert report.error is None
    built = build()
    got = _outputs(built, store)
    assert got == ref                               # no lost windows
    for key in ref:
        assert store.put_counts[key] == 1, key      # no duplicates either


@lru_cache(maxsize=1)
def _property_program():
    """One compiled tee'd program reused across property examples (the
    coordinator owns all run state; the program is immutable)."""
    return _tee_pipeline([], batch_records=64).build(
        num_buckets=16, n_workers=W, checkpoint_interval=3, job_id="fan-pt")


def _drive(built, events, crash_at=None):
    """Run the program over ``events``; with ``crash_at`` set, crash after
    that many records and resume a fresh coordinator over the same store +
    metadata.  Returns the final coordinator (tables, edges, outputs)."""
    store, meta = MemoryStore(), MetadataStore()
    if crash_at is not None:
        dead = StreamingCoordinator(store, meta, program=built)
        dead.run_stream(StreamSource.from_records(events[:crash_at],
                                                  batch_records=64),
                        announce=False, flush=False)
    coord = StreamingCoordinator(store, meta, program=built)
    coord.run_stream(StreamSource.from_records(events, batch_records=64),
                     announce=False, flush=True)
    return coord, _outputs(built, store)


@_PROPERTY_SETTINGS
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.05, 0.95),
       st.integers(2, 12))
def test_edge_key_tables_rebuild_bit_identically_after_restore(
        seed, crash_frac, n_keys):
    """Property: for any stream and any crash point, a mid-stream restore
    rebuilds every stage's key dictionary and every edge's bucket →
    next-key relabel table bit-identically to an uninterrupted run — and
    the emitted windows match byte for byte.  New keys keep arriving after
    the crash, so the tables must keep growing in the same first-seen
    order across the restore."""
    events = _events(n=700, n_keys=n_keys, span=280.0, seed=seed % 10_000)
    built = _property_program()
    crash_at = max(64, int(len(events) * crash_frac))
    plain, out_plain = _drive(built, events)
    crashed, out_crashed = _drive(built, events, crash_at=crash_at)
    assert out_plain and out_crashed == out_plain
    for st_a, st_b in zip(plain.stages, crashed.stages):
        dicts_a = [t.state_dict() for t in st_a.tables]
        dicts_b = [t.state_dict() for t in st_b.tables]
        assert dicts_a == dicts_b
    assert len(plain.edges) == len(crashed.edges) == 2
    for e_a, e_b in zip(plain.edges, crashed.edges):
        assert (e_a.relabel is None) == (e_b.relabel is None)
        if e_a.relabel is not None:
            assert np.array_equal(e_a.relabel, e_b.relabel), \
                (e_a.relabel, e_b.relabel)


@pytest.mark.slow
def test_tee_shard_map_matches_vmap():
    """The fan-out keeps the flat global wire under shard_map: a tee'd
    graph over a real mesh axis — with a mid-stream crash/restore — must
    emit byte-identical windows to the vmap drive on both sinks."""
    import os
    import subprocess
    import sys
    code = """
import jax, numpy as np
from repro.core import MemoryStore, MetadataStore
from repro.pipeline import Pipeline, Windowing
from repro.streaming import StreamSource, write_event_log
mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("workers",))
events = [(float(t), f"k{t % 7}", float(t % 5)) for t in range(800)]
def build(backend, m):
    base = (Pipeline.from_source(prefix="streams/ev", batch_records=100)
            .key_by().window(Windowing.tumbling(20.0)).reduce("count"))
    return base.tee(
        Pipeline.branch().window(Windowing.tumbling(100.0)).reduce("sum")
                .top_k(3).sink("smt-top/"),
        Pipeline.branch().map(lambda r: (r[0], r[1].upper(), r[2])).key_by()
                .window(Windowing.tumbling(100.0)).reduce("sum")
                .sink("smt-roll/"),
    ).build(num_buckets=28, n_workers=4, job_id="smt",
            backend=backend, mesh=m, checkpoint_interval=3)
outs = {}
for backend, m in (("vmap", None), ("shard_map", mesh)):
    store, meta = MemoryStore(), MetadataStore()
    write_event_log(store, "streams/ev", events)
    built = build(backend, m)
    if backend == "shard_map":
        built.run_streaming(store, meta, flush=False,
                            source=StreamSource.from_records(
                                events[:400], batch_records=100))
    rep = built.run_streaming(store, meta)
    assert rep.error is None
    outs[backend] = built.collect_outputs(store)
assert outs["vmap"] and outs["vmap"] == outs["shard_map"]
print("OK")
"""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src"}
    proc = subprocess.run([sys.executable, "-c", code],
                          cwd=os.path.dirname(os.path.dirname(__file__)),
                          env={**os.environ, **env},
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


# ---------------------------------------------------------------------------
# Joins over multi-stage inputs
# ---------------------------------------------------------------------------

def _two_phase(records, w1, w2, agg1, agg2, batch_records=100):
    return (Pipeline.from_source(records=records,
                                 batch_records=batch_records)
            .key_by().window(Windowing.tumbling(w1)).reduce(agg1)
            .window(Windowing.tumbling(w2)).reduce(agg2))


def test_join_over_two_multistage_inputs():
    """A downstream join over two multi-stage inputs: each side's upstream
    stage feeds the join through its own carry-handoff edge, both modes
    agree byte for byte, and the joined content matches a host oracle."""
    left_ev = _events(n=900, seed=41)
    right_ev = _events(n=700, seed=42)
    left = _two_phase(left_ev, 5.0, 25.0, "count", "sum")
    right = _two_phase(right_ev, 5.0, 25.0, "sum", "sum")
    built = left.join(right).build(num_buckets=12, n_workers=W,
                                   job_id="msj")
    assert len(built.stages) == 3 and built.stages[2].is_join
    assert {(e.src, e.dst, e.dst_side) for e in built.edges} == \
        {(0, 2, 0), (1, 2, 1)}
    assert built.inputs == ((0, 0), (1, 0))
    streamed = _streamed(built, MemoryStore())
    batched, _ = built.run_batch(MemoryStore())
    assert streamed and streamed == batched

    def rollup(events, agg1):
        fine = defaultdict(Counter)
        for ts, k, v in events:
            fine[int(ts // 5.0)][k] += 1 if agg1 == "count" else v
        coarse = defaultdict(Counter)
        for idx, per_key in fine.items():
            for k, x in per_key.items():
                coarse[int(idx * 5.0 // 25.0)][k] += x
        return coarse

    lo, ro = rollup(left_ev, "count"), rollup(right_ev, "sum")
    got = {k.rsplit("/", 1)[1]: [json.loads(ln) for ln in v.splitlines()]
           for k, v in streamed.items()}
    for widx in lo:
        rows = dict(got[f"window-{widx * 25.0:.3f}-{(widx + 1) * 25.0:.3f}"])
        want = {k: [float(lo[widx][k]), float(ro[widx][k])]
                for k in lo[widx] if k in ro[widx]}
        assert rows == pytest.approx(want)


def test_join_mixed_single_and_multistage_side():
    """One single-stage side (raw external events) joined against a
    multi-stage side (carry-fed): the join's watermark advances to the
    minimum over its input channels, so neither a lagging carry nor a
    lagging external stream loses windows — asserted via batch parity."""
    left_ev = _events(n=800, seed=43)
    right_ev = _events(n=600, seed=44)
    left = (Pipeline.from_source(records=left_ev, batch_records=100)
            .key_by().window(Windowing.tumbling(25.0)).reduce("sum"))
    right = _two_phase(right_ev, 5.0, 25.0, "count", "sum")
    built = left.join(right).build(num_buckets=12, n_workers=W,
                                   job_id="mixj")
    assert len(built.stages) == 2
    assert built.inputs == ((1, 0), (0, 0))         # left lands in the join
    streamed = _streamed(built, MemoryStore())
    batched, _ = built.run_batch(MemoryStore())
    assert streamed and streamed == batched


# ---------------------------------------------------------------------------
# Stage-local build options
# ---------------------------------------------------------------------------

def test_per_stage_build_options_resolved_per_stageplan():
    """reduce(..., num_buckets=, n_slots=) overrides the build-wide
    defaults for that stage only — and since dense labels don't depend on
    the bucket width, the emitted bytes match the all-default build."""
    events = _events(n=1000, seed=51)
    p = (Pipeline.from_source(records=events, batch_records=200)
         .key_by().window(Windowing.tumbling(10.0))
         .reduce("count", num_buckets=32, n_slots=12)
         .window(Windowing.tumbling(40.0))
         .reduce("sum", num_buckets=8, n_slots=4))
    built = p.build(num_buckets=16, n_workers=W, n_slots=8, job_id="opts")
    assert [s.num_buckets for s in built.stages] == [32, 8]
    assert [s.n_slots for s in built.stages] == [12, 4]
    assert built.stages[0].handoff_device           # still an identity edge
    streamed = _streamed(built, MemoryStore())
    batched, _ = built.run_batch(MemoryStore())
    assert streamed and streamed == batched
    default = p.build(num_buckets=16, n_workers=W, n_slots=8,
                      job_id="opts")                # same job id: same keys
    base_out, _ = default.run_batch(MemoryStore())
    # stage-local sizing is an execution detail, not a semantics change
    assert {k.rsplit("/", 1)[1] for k in batched} == \
        {k.rsplit("/", 1)[1] for k in base_out}
    assert sorted(batched.values()) == sorted(base_out.values())


def test_per_stage_options_validated_at_lower_time():
    events = [(0.0, "a", 1.0)]
    base = Pipeline.from_source(records=events).key_by()
    with pytest.raises(PipelineError, match="divide by n_workers"):
        (base.window(10.0).reduce("sum", num_buckets=6)
         ).build(num_buckets=16, n_workers=W)
    with pytest.raises(PipelineError, match="cannot hold the window span"):
        (base.window(Windowing.sliding(40.0, 10.0))
         .reduce("sum", n_slots=3)).build(num_buckets=16, n_workers=W)
    with pytest.raises(PipelineError, match="window slots"):
        (base.window(10.0).reduce("sum", n_slots=1)
         ).build(num_buckets=16, n_workers=W)
    right = (Pipeline.from_source(records=events).window(10.0)
             .reduce("sum"))
    with pytest.raises(PipelineError, match="join's final stage"):
        (base.window(10.0).reduce("sum", num_buckets=8).join(right)
         ).build(num_buckets=16, n_workers=W)
    with pytest.raises(PipelineError, match="build-wide options"):
        (Pipeline.from_source(shards=np.zeros((W, 4, 3), np.float32))
         .map(lambda s: (s[:, 0], s[:, 1], s[:, 2] > 0))
         .reduce("sum", num_buckets=4)).build(num_buckets=16, n_workers=W)


# ---------------------------------------------------------------------------
# Graph validation
# ---------------------------------------------------------------------------

def test_tee_validation():
    events = [(0.0, "a", 1.0)]
    base = (Pipeline.from_source(records=events).key_by().window(10.0)
            .reduce("count"))
    def leaf(sink):
        return Pipeline.branch().window(100.0).reduce("sum").sink(sink)
    with pytest.raises(PipelineError, match="at least two branches"):
        base.tee(leaf("a/"))
    with pytest.raises(PipelineError, match="rooted at Pipeline.branch"):
        base.tee(leaf("a/"),
                 Pipeline.from_source(records=events).window(100.0)
                 .reduce("sum"))
    with pytest.raises(PipelineError, match="terminal node"):
        base.tee(leaf("a/"), leaf("b/")).sink("c/").build(
            num_buckets=8, n_workers=W)
    with pytest.raises(PipelineError, match="its own .sink"):
        base.tee(leaf("a/"),
                 Pipeline.branch().window(100.0).reduce("sum")
                 ).build(num_buckets=8, n_workers=W)
    with pytest.raises(PipelineError, match="distinct prefixes"):
        base.tee(leaf("a/"), leaf("a/")).build(num_buckets=8, n_workers=W)
    with pytest.raises(PipelineError, match="distinct prefixes"):
        # output keys drop the trailing slash, so these collide too
        base.tee(leaf("a"), leaf("a/")).build(num_buckets=8, n_workers=W)
    with pytest.raises(PipelineError, match="fans out a .reduced"):
        (Pipeline.from_source(records=events).key_by().window(10.0)
         .tee(leaf("a/"), leaf("b/"))).build(num_buckets=8, n_workers=W)
    with pytest.raises(PipelineError, match="session"):
        base.tee(leaf("a/"),
                 Pipeline.branch().window(Windowing.session(5.0))
                 .reduce("sum").sink("s/")
                 ).build(num_buckets=8, n_workers=W)
    right = (Pipeline.from_source(records=events).window(10.0)
             .reduce("sum"))
    with pytest.raises(PipelineError, match="tee and join"):
        (Pipeline.from_source(records=events).key_by().window(10.0)
         .reduce("sum").join(right).tee(leaf("a/"), leaf("b/"))
         ).build(num_buckets=8, n_workers=W)
