"""Per-kernel correctness: shape/dtype sweeps, Pallas (interpret=True) vs the
pure-jnp oracle in each kernel's ref.py."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention, flash_decode
from repro.kernels.fused_fold.kernel import fused_streaming_fold
from repro.kernels.fused_fold.ref import fused_streaming_fold_ref
from repro.kernels.flash_attention.ops import chunked_attention
from repro.kernels.flash_attention.ref import decode_ref, mha_ref
from repro.kernels.hash_combine.kernel import hash_combine
from repro.kernels.hash_combine.ref import hash_combine_ref
from repro.kernels.mamba_scan.kernel import selective_scan
from repro.kernels.mamba_scan.ops import decode_step
from repro.kernels.mamba_scan.ref import selective_scan_ref

RNG = np.random.default_rng(0)


def t(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype=dtype)


# -- hash_combine ---------------------------------------------------------------

@pytest.mark.parametrize("n,d,buckets,block_n", [
    (256, 1, 32, 128), (1000, 4, 64, 512), (4096, 16, 256, 512),
    (777, 8, 128, 256),
])
def test_hash_combine_sweep(n, d, buckets, block_n):
    keys = jnp.asarray(RNG.integers(0, buckets, n), jnp.int32)
    vals = t((n, d))
    valid = jnp.asarray(RNG.random(n) > 0.2)
    got = hash_combine(keys, vals, valid, num_buckets=buckets,
                       block_n=block_n, interpret=True)
    want = hash_combine_ref(keys, vals, buckets, valid)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hash_combine_dtypes(dtype):
    keys = jnp.asarray(RNG.integers(0, 32, 512), jnp.int32)
    vals = t((512,), dtype)
    got = hash_combine(keys, vals, num_buckets=32, interpret=True)
    want = hash_combine_ref(keys, vals, 32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2)


# -- flash attention ---------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window,cap", [
    (1, 4, 4, 256, 256, 64, True, None, None),
    (2, 8, 2, 256, 256, 128, True, None, None),      # GQA
    (1, 2, 1, 256, 256, 64, True, 128, None),        # sliding window
    (1, 4, 4, 256, 256, 64, True, None, 50.0),       # softcap (gemma2)
    (2, 4, 2, 256, 256, 64, False, None, None),      # bidirectional
    (1, 4, 2, 128, 384, 64, True, None, None),       # skv > sq
])
def test_flash_attention_sweep(b, hq, hkv, sq, skv, d, causal, window, cap):
    q, k, v = t((b, hq, sq, d)), t((b, hkv, skv, d)), t((b, hkv, skv, d))
    got = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          interpret=True)
    want = mha_ref(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=2e-5)


def test_flash_attention_bf16():
    q, k, v = (t((1, 4, 256, 64), jnp.bfloat16) for _ in range(3))
    got = flash_attention(q, k, v, interpret=True)
    want = mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_chunked_attention_matches_kernel_path():
    q, k, v = t((2, 4, 256, 64)), t((2, 2, 256, 64)), t((2, 2, 256, 64))
    a = chunked_attention(q, k, v, causal=True, chunk=64)
    b_ = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(a, b_, rtol=1e-4, atol=2e-5)


@pytest.mark.parametrize("b,hq,hkv,smax,d,window,cap", [
    (2, 8, 2, 1024, 64, None, None),
    (1, 4, 4, 512, 128, None, None),
    (2, 8, 4, 2048, 64, 512, None),                  # windowed decode
    (1, 16, 8, 1024, 64, None, 30.0),
])
def test_flash_decode_sweep(b, hq, hkv, smax, d, window, cap):
    q = t((b, hq, d))
    kc, vc = t((b, hkv, smax, d)), t((b, hkv, smax, d))
    lengths = jnp.asarray(RNG.integers(smax // 4, smax, b), jnp.int32)
    got = flash_decode(q, kc, vc, lengths, window=window, softcap=cap,
                       interpret=True)
    want = decode_ref(q, kc, vc, lengths, window=window, softcap=cap)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=2e-5)


# -- mamba selective scan ---------------------------------------------------------

@pytest.mark.parametrize("b,L,d,n,bd,bl", [
    (2, 512, 256, 16, 128, 256), (1, 256, 512, 16, 256, 128),
    (2, 128, 128, 8, 128, 64),
])
def test_selective_scan_sweep(b, L, d, n, bd, bl):
    u = t((b, L, d))
    delta = jnp.asarray(np.abs(RNG.normal(size=(b, L, d))) * 0.1 + 0.01,
                        jnp.float32)
    A = -jnp.asarray(np.abs(RNG.normal(size=(d, n))) + 0.5, jnp.float32)
    B, C, D = t((b, L, n)), t((b, L, n)), t((d,))
    y_k, h_k = selective_scan(u, delta, A, B, C, D, block_d=bd, block_l=bl,
                              interpret=True)
    y_r, h_r = selective_scan_ref(u, delta, A, B, C, D)
    np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h_k, h_r, rtol=1e-4, atol=1e-4)


def test_scan_decode_step_matches_full_scan():
    """Running decode_step over a sequence equals the full scan — the
    serving-path invariant behind long_500k."""
    b, L, d, n = 1, 16, 32, 8
    u = t((b, L, d))
    delta = jnp.asarray(np.abs(RNG.normal(size=(b, L, d))) * 0.1 + 0.01,
                        jnp.float32)
    A = -jnp.asarray(np.abs(RNG.normal(size=(d, n))) + 0.5, jnp.float32)
    B, C, D = t((b, L, n)), t((b, L, n)), t((d,))
    y_full, h_full = selective_scan_ref(u, delta, A, B, C, D)
    h = jnp.zeros((b, d, n), jnp.float32)
    ys = []
    for i in range(L):
        y_t, h = decode_step(h, u[:, i], delta[:, i], A, B[:, i], C[:, i], D)
        ys.append(y_t)
    np.testing.assert_allclose(jnp.stack(ys, 1), y_full, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h, h_full, rtol=1e-4, atol=1e-4)


def test_ssd_chunked_matches_recurrence():
    """Mamba-2 SSD chunked form vs the naive recurrence."""
    from repro.models.mamba import _ssd_chunked
    b, slen, h, p, n, chunk = 1, 64, 4, 8, 16, 16
    x = t((b, slen, h, p))
    dt = jnp.asarray(np.abs(RNG.normal(size=(b, slen, h))) * 0.1 + 0.01,
                     jnp.float32)
    A = -jnp.asarray(np.abs(RNG.normal(size=(h,))) + 0.3, jnp.float32)
    B, C = t((b, slen, n)), t((b, slen, n))
    y, s_final = _ssd_chunked(x, dt, A, B, C, chunk)
    # naive
    s = np.zeros((b, h, n, p), np.float32)
    ys = np.zeros((b, slen, h, p), np.float32)
    xn, dtn, Bn, Cn = map(np.asarray, (x, dt, B, C))
    An = np.asarray(A)
    for i in range(slen):
        decay = np.exp(dtn[:, i] * An[None])                     # (b, h)
        dBx = np.einsum("bh,bn,bhp->bhnp", dtn[:, i], Bn[:, i], xn[:, i])
        s = decay[..., None, None] * s + dBx
        ys[:, i] = np.einsum("bn,bhnp->bhp", Cn[:, i], s)
    np.testing.assert_allclose(y, ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s_final, s, rtol=2e-4, atol=2e-4)


# -- fused_fold (hash -> window fan-out -> scatter-accumulate) ------------------

def _device_rows(n, *, fanout, n_slots, keymax, rng):
    """5-col device wire [last_window, n_windows, key, value, valid] with
    integer-valued payloads so fp32 sums are exact in any order."""
    cols = [rng.integers(0, 3 * n_slots, n), rng.integers(1, fanout + 1, n),
            rng.integers(0, keymax, n), rng.integers(-20, 100, n),
            rng.random(n) > 0.15]
    return jnp.asarray(np.stack(cols, axis=1), jnp.float32)


def _host_rows(n, *, n_slots, keymax, rng):
    """4-col host wire [window_slot, key, value, valid] (fan-out 1)."""
    cols = [rng.integers(0, n_slots, n), rng.integers(0, keymax, n),
            rng.integers(-20, 100, n), rng.random(n) > 0.15]
    return jnp.asarray(np.stack(cols, axis=1), jnp.float32)


@pytest.mark.parametrize("kind", ["sum", "count", "min", "max"])
@pytest.mark.parametrize("hashed", [False, True], ids=["dense", "hashed"])
def test_fused_fold_device_wire_sweep(kind, hashed):
    """Kernel (interpret) vs the XLA ref on the 5-col device wire: fan-out
    4, a min_window that drops some fan-outs as late, a ragged batch that
    exercises the zero-pad tail, and multi-tile carry grid (block_s)."""
    rng = np.random.default_rng(7)
    n_slots, nb = 6, 24
    kw = dict(fanout=4, n_slots=n_slots, num_buckets=nb, carry_buckets=nb,
              hashed=hashed, kind=kind)
    rows = _device_rows(999, fanout=4, n_slots=n_slots,
                        keymax=(1 << 20) if hashed else nb, rng=rng)
    carry = jnp.asarray(rng.integers(0, 5, (n_slots * nb, 2)), jnp.float32)
    if kind in ("min", "max"):
        carry = carry.at[:, 0].set(          # honour the carry contract:
            jnp.where(carry[:, 1] > 0, carry[:, 0], 0.0))   # count 0 -> 0.0
    got_c, got_s = fused_streaming_fold(rows, carry, 2, block_n=256,
                                        block_s=48, interpret=True, **kw)
    want_c, want_s = fused_streaming_fold_ref(rows, carry, 2, **kw)
    assert np.array_equal(np.asarray(got_c), np.asarray(want_c))
    assert np.array_equal(np.asarray(got_s), np.asarray(want_s))
    assert int(got_s[0]) > 0                 # min_window really dropped some


@pytest.mark.parametrize("kind", ["sum", "count", "min", "max"])
def test_fused_fold_host_wire_sweep(kind):
    rng = np.random.default_rng(11)
    n_slots, nb = 4, 16
    kw = dict(fanout=1, n_slots=n_slots, num_buckets=nb, carry_buckets=nb,
              hashed=True, host_wire=True, kind=kind)
    rows = _host_rows(500, n_slots=n_slots, keymax=1 << 20, rng=rng)
    carry = jnp.zeros((n_slots * nb, 2), jnp.float32)
    got_c, got_s = fused_streaming_fold(rows, carry, block_n=128,
                                        interpret=True, **kw)
    want_c, want_s = fused_streaming_fold_ref(rows, carry, **kw)
    assert np.array_equal(np.asarray(got_c), np.asarray(want_c))
    assert np.array_equal(np.asarray(got_s), np.asarray(want_s))


def test_fused_fold_channel_embedding_leaves_neighbours():
    """A shared carry (joins): fold into channels [2, 4) of a 6-channel
    slab over carry_buckets > num_buckets — the other channels and the
    out-of-range bucket rows must come back bit-identical."""
    rng = np.random.default_rng(13)
    n_slots, nb, cb = 4, 12, 16
    kw = dict(fanout=2, n_slots=n_slots, num_buckets=nb, carry_buckets=cb,
              channel_base=2, kind="sum")
    rows = _device_rows(300, fanout=2, n_slots=n_slots, keymax=nb, rng=rng)
    carry = jnp.asarray(rng.integers(0, 9, (n_slots * cb, 6)), jnp.float32)
    got_c, _ = fused_streaming_fold(rows, carry, block_n=128,
                                    interpret=True, **kw)
    want_c, _ = fused_streaming_fold_ref(rows, carry, **kw)
    assert np.array_equal(np.asarray(got_c), np.asarray(want_c))
    got, old = np.asarray(got_c), np.asarray(carry)
    assert np.array_equal(got[:, [0, 1, 4, 5]], old[:, [0, 1, 4, 5]])
    untouched = np.arange(n_slots * cb) % cb >= nb    # buckets [nb, cb)
    assert np.array_equal(got[untouched], old[untouched])


def test_fused_fold_tiling_invariance():
    """The grid decomposition is an implementation detail: any
    (block_n, block_s) pair must produce the same bytes."""
    rng = np.random.default_rng(17)
    n_slots, nb = 8, 32
    kw = dict(fanout=3, n_slots=n_slots, num_buckets=nb, carry_buckets=nb,
              hashed=True, kind="sum")
    rows = _device_rows(700, fanout=3, n_slots=n_slots, keymax=1 << 20,
                        rng=rng)
    carry = jnp.zeros((n_slots * nb, 2), jnp.float32)
    ref = None
    for block_n, block_s in [(128, None), (256, 64), (512, 128), (1024, 32)]:
        c, s = fused_streaming_fold(rows, carry, 1, block_n=block_n,
                                    block_s=block_s, interpret=True, **kw)
        if ref is None:
            ref = (np.asarray(c), np.asarray(s))
        assert np.array_equal(np.asarray(c), ref[0]), (block_n, block_s)
        assert np.array_equal(np.asarray(s), ref[1]), (block_n, block_s)


def test_fused_fold_in_kernel_hash_matches_engine():
    """The kernel duplicates the murmur bucketizer rather than importing
    the engine (kernels stay dependency-free) — pin the two to the same
    bits so they cannot drift apart."""
    from repro.engine.stages import device_hash
    from repro.kernels.fused_fold.ref import murmur_bucket
    keys = jnp.asarray(np.random.default_rng(19).integers(0, 1 << 24, 4096),
                       jnp.float32)
    want = (device_hash(keys.astype(jnp.uint32)) % jnp.uint32(64)
            ).astype(jnp.int32)
    got = murmur_bucket(keys, 64, True)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_shuffle_aggregate_pallas_combiner_parity():
    """`combine_fn="pallas"` routes the batch shuffle through the
    hash_combine kernel (vmap-of-pallas, interpret off-TPU) — same bytes
    as the default dense jnp combiner, through the reduce_scatter."""
    import jax
    from repro.core.shuffle import resolve_combine_fn, shuffle_aggregate
    rng = np.random.default_rng(23)
    W, n, nb = 4, 256, 32
    keys = jnp.asarray(rng.integers(0, nb, (W, n)), jnp.int32)
    vals = jnp.asarray(rng.integers(0, 50, (W, n)), jnp.float32)
    valid = jnp.asarray(rng.random((W, n)) > 0.2)

    def run(combine_fn):
        f = jax.vmap(lambda k, v, ok: shuffle_aggregate(
            k, v, "w", nb, valid=ok, combine_fn=combine_fn), axis_name="w")
        return np.asarray(f(keys, vals, valid))

    assert np.array_equal(run("pallas"), run(None))
    # the resolved callable is the kernel product, not the jnp fallback
    from repro.engine.stages import local_combine_dense
    assert resolve_combine_fn("pallas") is not local_combine_dense
    assert resolve_combine_fn(None) is local_combine_dense
