"""Streaming layer: window-assignment boundaries, watermark finalization
order, ring-slot reuse (and the overflow error path), single-writer
late-drop accounting vs a host-numpy oracle, session gap-merge under
shuffled arrival, replayable sources, backpressure scaling, and agreement
of incremental per-window aggregates with a one-shot batch run."""

import json
from collections import defaultdict

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic container: seeded-sampling shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (AutoscalerConfig, MemoryStore, MetadataStore,
                        ServerlessPool)
from repro.core.events import EventBus, TOPIC_STREAM_WINDOW
from repro.core.mapreduce import (DeviceJobConfig, clear_window_slot,
                                  init_window_carry, make_incremental_step,
                                  read_window_slot)
from repro.pipeline import Pipeline, Windowing
from repro.streaming import (LateEventError, SessionTracker, SlidingWindows,
                             StreamSource, StreamingCoordinator,
                             TumblingWindows, WindowTracker,
                             window_output_key, write_event_log)

# scoped per-test (no global load_profile: that would silently shrink every
# other module's property tests for the whole session)
_PROPERTY_SETTINGS = settings(max_examples=15, deadline=None)


# ---------------------------------------------------------------------------
# Window assignment
# ---------------------------------------------------------------------------

def test_tumbling_boundaries_half_open():
    w = TumblingWindows(60.0)
    # an event exactly on a window edge belongs to the window starting there
    assert w.assign(0.0) == [0]
    assert w.assign(59.999) == [0]
    assert w.assign(60.0) == [1]
    assert w.assign(-0.001) == [-1]
    win = w.window(1)
    assert (win.start, win.end) == (60.0, 120.0)
    assert 60.0 in win and 120.0 not in win


def test_sliding_membership_and_edges():
    w = SlidingWindows(size=4.0, slide=2.0)
    # ts=4.0 sits in [2,6) and [4,8) but NOT [0,4) — half-open edge
    assert w.assign(4.0) == [1, 2]
    assert w.assign(3.9) == [0, 1]
    assert w.max_windows_per_event() == 2
    for ts in np.linspace(0, 20, 101):
        wins = w.assign(float(ts))
        assert all(ts in w.window(i) for i in wins)
        assert len(wins) <= w.max_windows_per_event()


def test_sliding_nondivisible_fanout():
    w = SlidingWindows(size=5.0, slide=2.0)
    assert w.max_windows_per_event() == 3
    assert len(w.assign(4.5)) == 3


def test_sliding_rejects_gappy_config():
    with pytest.raises(ValueError):
        SlidingWindows(size=1.0, slide=2.0)


# ---------------------------------------------------------------------------
# Watermark + window ring
# ---------------------------------------------------------------------------

def test_watermark_finalization_order():
    t = WindowTracker(TumblingWindows(10.0), n_slots=4)
    # windows arrive out of order
    for widx in (2, 0, 1):
        assert t.slot_for(widx) is not None
    t.observe(25.0)  # watermark passes windows 0 [0,10) and 1 [10,20)
    ripe = t.ripe()
    assert [w for w, _ in ripe] == [0, 1]  # start order, not arrival order
    for w, _ in ripe:
        t.release(w)
    assert list(t.active) == [2]
    t.observe(35.0)
    assert [w for w, _ in t.ripe()] == [2]


def test_late_events_dropped_after_finalization():
    t = WindowTracker(TumblingWindows(10.0), n_slots=4, allowed_lateness=5.0)
    assert t.slot_for(0) is not None
    t.observe(12.0)                 # watermark 7 < 10: window 0 still open
    assert not t.is_late(0)
    t.observe(16.0)                 # watermark 11 >= 10: window 0 closes
    for w, _ in t.ripe():
        t.release(w)
    assert t.slot_for(0) is None    # late event → must be dropped
    # admission never self-counts: note_late is the single writer, so a
    # pair dropped host-side and a pair masked on-device can't double in
    assert t.late_dropped == 0
    t.note_late(1)
    assert t.late_dropped == 1


def test_slot_reuse_and_ring_overflow():
    t = WindowTracker(TumblingWindows(10.0), n_slots=2)
    s0 = t.slot_for(0)
    t.slot_for(1)
    with pytest.raises(LateEventError):
        t.slot_for(2)               # ring full, window 2 not late
    t.observe(10.0)
    for w, _ in t.ripe():
        t.release(w)
    assert t.slot_for(2) == s0      # freed slot recycled


def test_ring_overflow_error_names_the_blocking_window():
    """The overflow error path: the raised LateEventError identifies the
    colliding modular slot and its still-active owner, and raising leaves
    the tracker untouched (no half-claimed slot, no phantom late count)."""
    t = WindowTracker(TumblingWindows(5.0), n_slots=3)
    t.slot_for(4)                   # slot 1
    before = dict(t.active)
    with pytest.raises(LateEventError, match=r"slot 1 of 3.*window 4"):
        t.slot_for(7)               # 7 % 3 == 1, still owned by window 4
    assert t.active == before and t.late_dropped == 0


@_PROPERTY_SETTINGS
@given(st.integers(2, 6), st.lists(st.integers(0, 40), min_size=1,
                                   max_size=60))
def test_ring_overflow_property(n_slots, windows):
    """Property: ``slot_for`` either returns the modular slot (claiming it
    exactly once), returns None for a closed window, or raises
    LateEventError precisely when the modular slot is owned by a
    *different* active window — and never corrupts the slot table."""
    t = WindowTracker(TumblingWindows(1.0), n_slots=n_slots)
    for w in windows:
        owner = {s: wi for wi, s in t.active.items()}.get(w % n_slots)
        late = w not in t.active and t.is_late(w)
        try:
            slot = t.slot_for(w)
        except LateEventError:
            assert not late and owner is not None and owner != w
            continue
        if late:
            assert slot is None         # closed window: dropped, not claimed
        else:
            assert slot == w % n_slots and owner in (None, w)
        # drain once the ring fills so slots free up mid-sequence
        if len(t.active) == n_slots:
            t.observe(max(wi + 1 for wi in t.active))
            for wi, _s in t.ripe():
                t.release(wi)
        assert len(t.active) == len({s for s in t.active.values()})


# ---------------------------------------------------------------------------
# Late-drop accounting: one writer, oracle-exact
# ---------------------------------------------------------------------------

def _late_oracle(events, assigner, batch_records, lateness):
    """Host-numpy reference: the watermark advances to each batch's max
    event time − lateness *after* the batch; a (record, window) pair is
    dropped iff its window's end had already been passed when its batch
    was processed.  Valid as long as the ring never fills mid-batch."""
    wm = float("-inf")
    dropped = 0
    for i in range(0, len(events), batch_records):
        batch = events[i:i + batch_records]
        for ts, _k, _v in batch:
            dropped += sum(assigner.window(w).end <= wm
                           for w in assigner.assign(ts))
        wm = max(wm, max(ts for ts, _k, _v in batch) - lateness)
    return dropped


def _disordered_events(n=2500, seed=0, spread=8.0):
    rng = np.random.default_rng(seed)
    ts = np.cumsum(rng.exponential(0.1, n)) + rng.uniform(-spread, spread, n)
    return [(float(t), f"k{i % 6}", 1.0) for i, t in enumerate(ts)]


@pytest.mark.parametrize("windowing,assigner", [
    (Windowing.tumbling(10.0), TumblingWindows(10.0)),
    (Windowing.sliding(20.0, 5.0), SlidingWindows(20.0, 5.0)),
])
@pytest.mark.parametrize("fanout", ["device", "host"])
def test_late_dropped_matches_host_oracle(windowing, assigner, fanout):
    """Regression: under out-of-order input with allowed_lateness > 0,
    ``late_dropped`` equals the host-numpy oracle exactly — each dropped
    (record, window) pair is counted once, whether the host admission
    refused it or the device fan-out masked it (note_late is the single
    writer on both paths)."""
    events = _disordered_events(seed=5)
    lateness = 3.0
    built = (Pipeline.from_source(records=events, batch_records=200)
             .key_by().window(windowing).reduce("count")
             .build(num_buckets=12, n_workers=4, n_slots=12,
                    allowed_lateness=lateness, fanout=fanout,
                    job_id=f"late-{windowing.kind}-{fanout}"))
    report = built.run_streaming(MemoryStore(), MetadataStore())
    want = _late_oracle(events, assigner, 200, lateness)
    assert want > 0                      # the input really is disordered
    assert report.late_dropped == want


@pytest.mark.slow
def test_late_dropped_host_and_device_fanout_agree_under_ring_pressure():
    """Mid-batch ring-full finalization advances the watermark inside a
    batch; the host- and device-fan-out paths must still count the exact
    same set of dropped pairs."""
    rng = np.random.default_rng(9)
    n = 3000
    ts = np.cumsum(rng.exponential(0.5, n)) + rng.uniform(-12.0, 12.0, n)
    events = [(float(t), f"k{i % 5}", 1.0) for i, t in enumerate(ts)]
    counts = {}
    for fanout in ("device", "host"):
        built = (Pipeline.from_source(records=events, batch_records=1000)
                 .key_by().window(Windowing.sliding(10.0, 2.5))
                 .reduce("count")
                 .build(num_buckets=10, n_workers=2, n_slots=8,
                        allowed_lateness=3.0, fanout=fanout,
                        job_id=f"ring-{fanout}"))
        report = built.run_streaming(MemoryStore(), MetadataStore())
        counts[fanout] = report.late_dropped
    assert counts["device"] == counts["host"] > 0


# ---------------------------------------------------------------------------
# Session gap-merge under shuffled arrival (property-style)
# ---------------------------------------------------------------------------

def _session_bounds_reference(times, gap):
    """Sorted-order reference: maximal runs with no gap > ``gap``;
    session [first, last + gap)."""
    out = []
    run = [times[0]]
    for t in times[1:]:
        if t - run[-1] > gap:
            out.append((run[0], run[-1] + gap))
            run = []
        run.append(t)
    out.append((run[0], run[-1] + gap))
    return sorted(out)


@_PROPERTY_SETTINGS
@given(st.lists(st.floats(0.0, 200.0, allow_nan=False), min_size=1,
                max_size=40),
       st.floats(0.5, 10.0, allow_nan=False),
       st.integers(0, 1 << 30))
def test_session_gap_merge_shuffled_order_property(times, gap, shuffle_seed):
    """Property: whatever order events arrive in (no watermark pressure),
    the tracker's finalized sessions are exactly the maximal gap-runs of
    the sorted event times — bridging events merge open sessions so the
    final bounds are arrival-order independent."""
    times = sorted(round(t, 3) for t in times)
    shuffled = list(times)
    np.random.default_rng(shuffle_seed).shuffle(shuffled)
    t = SessionTracker(gap=gap, n_slots=len(times) + 1)
    for ts in shuffled:
        admitted = t.admit(0, ts)
        assert admitted is not None     # watermark never advanced: no drops
    t.observe(float("inf"))
    got = sorted((s.start, s.end) for s in t.ripe())
    want = _session_bounds_reference(times, gap)
    assert got == pytest.approx(want)
    assert t.late_dropped == 0


# ---------------------------------------------------------------------------
# Device-engine incremental fold
# ---------------------------------------------------------------------------

def test_incremental_step_matches_oracle_and_clear():
    rng = np.random.default_rng(1)
    cfg = DeviceJobConfig(num_buckets=8, n_workers=4)
    n_slots = 4
    step = make_incremental_step(cfg, n_slots)
    carry = init_window_carry(cfg, n_slots)
    want = np.zeros((n_slots, 8, 2), np.float32)
    for _ in range(3):  # several batches fold into the same carry
        rows = np.zeros((4, 16, 4), np.float32)
        for w in range(4):
            for i in range(16):
                slot, key = rng.integers(0, n_slots), rng.integers(0, 8)
                val = float(rng.integers(0, 10))
                rows[w, i] = (slot, key, val, 1.0)
                want[slot, key] += (val, 1.0)
        carry = step(rows, carry)
    for slot in range(n_slots):
        got = read_window_slot(carry, slot, 8)
        assert np.array_equal(got, want[slot])
    carry = clear_window_slot(carry, 1, 8)
    assert np.all(read_window_slot(carry, 1, 8) == 0)
    assert np.array_equal(read_window_slot(carry, 0, 8), want[0])


def test_invalid_rows_do_not_contribute():
    cfg = DeviceJobConfig(num_buckets=4, n_workers=2)
    step = make_incremental_step(cfg, 2)
    carry = init_window_carry(cfg, 2)
    rows = np.zeros((2, 4, 4), np.float32)
    rows[0, 0] = (0, 1, 5.0, 1.0)
    rows[1, 0] = (0, 0, 7.0, 0.0)   # invalid: must be ignored
    carry = step(rows, carry)
    agg = read_window_slot(carry, 0, 4)
    assert agg[1, 0] == 5.0 and agg[1, 1] == 1.0
    assert agg[0, 0] == 0.0 and agg[0, 1] == 0.0


# ---------------------------------------------------------------------------
# StreamSource
# ---------------------------------------------------------------------------

def test_source_replay_is_deterministic_and_bounded():
    store = MemoryStore()
    events = [(float(i), i % 5, float(i)) for i in range(250)]
    assert write_event_log(store, "s/log", events, segment_records=64) == 250
    src = StreamSource(store=store, prefix="s/log", batch_records=32)
    b1 = list(src.batches())
    b2 = list(src.batches())        # replay: same batches, same order
    assert [b.records for b in b1] == [b.records for b in b2]
    assert all(len(b) <= 32 for b in b1)
    assert sum(len(b) for b in b1) == 250
    assert [b.index for b in b1] == list(range(len(b1)))
    # resume skips processed records (record-addressed, not batch-addressed)
    tail = list(src.batches(start_record=5 * 32))
    assert [b.records for b in tail] == [b.records for b in b1[5:]]


def test_event_log_appends_new_segments():
    store = MemoryStore()
    write_event_log(store, "s/log", [(0.0, "a", 1.0)])
    write_event_log(store, "s/log", [(1.0, "b", 2.0)])
    src = StreamSource(store=store, prefix="s/log", batch_records=10)
    assert [k for _, k, _ in src.events()] == ["a", "b"]


# ---------------------------------------------------------------------------
# End to end: incremental == one-shot batch, bit for bit
# ---------------------------------------------------------------------------

def _synth_events(n=4000, n_keys=12, seed=3):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.uniform(0, 200.0, n))
    keys = rng.integers(0, n_keys, n)
    vals = rng.integers(0, 50, n).astype(float)  # integer-valued → exact fp32
    return [(float(t), f"k{k}", float(v))
            for t, k, v in zip(ts, keys, vals)]


def _build(job_id, *, aggregation="sum", window_size=50.0, window_slide=None,
           batch_records=100, num_buckets=16, n_workers=4, **build_opts):
    """The canonical single-chain streaming program these tests drive —
    what the removed flat ``StreamingConfig`` used to lower itself to."""
    w = (Windowing.sliding(window_size, window_slide) if window_slide
         else Windowing.tumbling(window_size))
    p = (Pipeline.from_source(batch_records=batch_records).key_by()
         .window(w).reduce(aggregation).sink("stream-output/"))
    return p.build(num_buckets=num_buckets, n_workers=n_workers,
                   batch_records=batch_records, job_id=job_id, **build_opts)


def _run(events, batch_records, aggregation="sum", job_id="j"):
    built = _build(job_id, aggregation=aggregation,
                   batch_records=batch_records)
    store = MemoryStore()
    coord = StreamingCoordinator(store, MetadataStore(), program=built)
    report = coord.run_stream(
        StreamSource.from_records(events, batch_records=batch_records))
    out = {}
    for m in store.list_objects(f"stream-output/{job_id}/"):
        win = m.key.rsplit("/", 1)[1]
        out[win] = dict(json.loads(line)
                        for line in store.get(m.key).splitlines())
    return out, report


@pytest.mark.parametrize("aggregation", ["count", "sum", "mean"])
def test_incremental_matches_one_shot_batch(aggregation):
    events = _synth_events()
    # incremental: many small micro-batches; one-shot: a single batch
    inc, rep_inc = _run(events, 256, aggregation, "inc")
    one, rep_one = _run(events, len(events), aggregation, "one")
    assert rep_one.batches == 1 and rep_inc.batches > 10
    assert inc.keys() == one.keys()
    for win in inc:
        assert inc[win] == one[win], win   # bit-for-bit (ints exact in fp32)
    # and both agree with a host-side oracle
    oracle = defaultdict(lambda: defaultdict(list))
    for ts, k, v in events:
        oracle[int(ts // 50.0)][k].append(v)
    assert len(inc) == len(oracle)
    for widx, per_key in oracle.items():
        win = f"window-{widx * 50.0:.3f}-{(widx + 1) * 50.0:.3f}"
        for k, vs in per_key.items():
            want = {"count": len(vs), "sum": sum(vs),
                    "mean": sum(vs) / len(vs)}[aggregation]
            assert inc[win][k] == pytest.approx(want, abs=1e-5)


def test_sliding_windows_end_to_end():
    events = _synth_events(n=1000)
    built = _build("slide", aggregation="count", window_slide=25.0,
                   n_slots=8, batch_records=128)
    store = MemoryStore()
    coord = StreamingCoordinator(store, MetadataStore(), program=built)
    report = coord.run_stream(
        StreamSource.from_records(events, batch_records=128))
    # every event lands in exactly two overlapping windows
    assert report.records_expanded == 2 * report.records_in
    oracle = defaultdict(int)
    for ts, _k, _v in events:
        for widx in SlidingWindows(50.0, 25.0).assign(ts):
            oracle[widx] += 1
    for widx, n in oracle.items():
        key = window_output_key(built, built.assigner().window(widx))
        got = dict(json.loads(line)
                   for line in store.get(key).splitlines())
        assert sum(got.values()) == n


def test_watermark_emission_order_and_bus_events():
    events = _synth_events(n=2000)
    built = _build("order", window_size=20.0)
    bus = EventBus()
    coord = StreamingCoordinator(MemoryStore(), MetadataStore(), bus=bus,
                                 program=built)
    coord.run_stream(StreamSource.from_records(events, batch_records=100))
    recs = bus.poll("sub", TOPIC_STREAM_WINDOW, timeout=0.1, max_records=100)
    per_part = defaultdict(list)
    for r in recs:
        per_part[r.partition].append(r.value.data["window_start"])
    # per partition (Kafka's ordering unit) windows arrive in time order
    assert all(starts == sorted(starts) for starts in per_part.values())
    all_starts = sorted(s for ss in per_part.values() for s in ss)
    assert all_starts == [i * 20.0 for i in range(len(all_starts))]


def test_crash_resume_is_exact():
    """A coordinator restarted mid-stream restores carry + watermark +
    key dictionary from the checkpoint and produces bit-identical windows
    to an uninterrupted run — including windows straddling the crash."""
    events = _synth_events(n=1000, seed=9)

    built = _build("crash")

    def make(store, meta):
        return StreamingCoordinator(store, meta, program=built)

    # uninterrupted reference run
    ref_store = MemoryStore()
    make(ref_store, MetadataStore()).run_stream(
        StreamSource.from_records(events, batch_records=100))

    # crashed run: first coordinator sees only the first 5 batches, then a
    # fresh coordinator resumes over the full log
    store, meta = MemoryStore(), MetadataStore()
    make(store, meta).run_stream(
        StreamSource.from_records(events[:500], batch_records=100),
        flush=False)
    report = make(store, meta).run_stream(
        StreamSource.from_records(events, batch_records=100))
    assert report.batches == 5            # only the unprocessed tail
    assert report.max_lag <= 5            # no phantom lag from replayed triggers

    ref = {m.key: ref_store.get(m.key)
           for m in ref_store.list_objects("stream-output/crash/")}
    got = {m.key: store.get(m.key)
           for m in store.list_objects("stream-output/crash/")}
    assert ref and got == ref             # bit-for-bit, every window


def test_sparse_checkpoint_resume_replays_tail():
    """checkpoint_interval > 1: a crash between checkpoints replays the
    uncheckpointed tail from the replayable log and still converges to the
    uninterrupted result."""
    events = _synth_events(n=1000, seed=11)

    built = _build("sparse", checkpoint_interval=3)

    def make(store, meta):
        return StreamingCoordinator(store, meta, program=built)

    ref_store = MemoryStore()
    make(ref_store, MetadataStore()).run_stream(
        StreamSource.from_records(events, batch_records=100))

    store, meta = MemoryStore(), MetadataStore()
    make(store, meta).run_stream(
        StreamSource.from_records(events[:500], batch_records=100),
        flush=False)                       # 5 batches, checkpoint at 3
    report = make(store, meta).run_stream(
        StreamSource.from_records(events, batch_records=100))
    assert report.batches == 7             # batches 3..9 replayed/processed
    ref = {m.key: ref_store.get(m.key)
           for m in ref_store.list_objects("stream-output/sparse/")}
    got = {m.key: store.get(m.key)
           for m in store.list_objects("stream-output/sparse/")}
    assert ref and got == ref


def test_checkpointed_offset_resume():
    events = _synth_events(n=600)
    built = _build("resume", window_size=1e9)
    store, meta = MemoryStore(), MetadataStore()
    coord = StreamingCoordinator(store, meta, program=built)
    src = StreamSource.from_records(events, batch_records=100)
    coord.run_stream(src, flush=False)
    assert coord.checkpointed_offset() == 600   # records, not batches
    # a restarted coordinator consumes nothing new
    coord2 = StreamingCoordinator(store, meta, program=built)
    report = coord2.run_stream(src, announce=False, flush=False)
    assert report.batches == 0


def test_resume_over_grown_log_after_flush():
    """A flushed run must not poison the checkpoint with the +inf
    end-of-stream watermark, and growth past a partial final batch must not
    shift chunk boundaries: every appended event still lands in a window."""
    store, meta = MemoryStore(), MetadataStore()
    built = _build("grow", aggregation="count", window_size=10.0,
                   batch_records=20)
    # first run ends on a partial batch (50 % 20 != 0) and flushes
    write_event_log(store, "g/log", [(float(i), "k", 1.0) for i in range(50)])
    src = StreamSource(store=store, prefix="g/log", batch_records=20)
    StreamingCoordinator(store, meta, program=built).run_stream(src)
    # the log grows; a fresh coordinator resumes and must see every new event
    write_event_log(store, "g/log",
                    [(float(i), "k", 1.0) for i in range(50, 100)])
    r2 = StreamingCoordinator(store, meta, program=built).run_stream(src)
    assert r2.records_in == 50 and r2.late_dropped == 0
    total = 0
    for m in store.list_objects("stream-output/grow/"):
        total += sum(json.loads(line)[1]
                     for line in store.get(m.key).splitlines())
    assert total == 100                      # no event lost or double-counted


def test_oversized_source_batch_raises():
    """A source chunked larger than the coordinator's batch_records must
    fail loudly, not overflow the pre-sized device arrays."""
    events = [(float(i), "k", 1.0) for i in range(50)]
    built = _build("mismatch", window_size=100.0, batch_records=10)
    coord = StreamingCoordinator(MemoryStore(), MetadataStore(),
                                 program=built)
    with pytest.raises(ValueError, match="batch_records"):
        coord.run_stream(StreamSource.from_records(events, batch_records=50))


def test_batch_spanning_many_windows_folds_mid_batch():
    """A low-rate stream whose single micro-batch spans more windows than
    the ring holds must fold+finalize mid-batch, not abort."""
    # 300 events at 1 event/s, 10s tumbling windows → 30 windows in one batch
    events = [(float(i), "k", 1.0) for i in range(300)]
    built = _build("span", window_size=10.0, n_slots=4, batch_records=300)
    store = MemoryStore()
    report = StreamingCoordinator(store, MetadataStore(),
                                  program=built).run_stream(
        StreamSource.from_records(events, batch_records=300))
    assert report.error is None and report.late_dropped == 0
    totals = {}
    for m in store.list_objects("stream-output/span/"):
        for line in store.get(m.key).splitlines():
            k, v = json.loads(line)
            totals[m.key] = totals.get(m.key, 0) + v
    assert len(totals) == 30 and all(v == 10 for v in totals.values())


def test_reap_idle_respects_min_scale():
    pool = ServerlessPool("s", AutoscalerConfig(min_scale=2,
                                                scale_to_zero_grace=0.0))
    pool.ensure_scale(4)
    import time
    time.sleep(0.01)
    assert pool.reap_idle() == 2          # only down to the floor
    assert pool.replicas() == 2


def test_ring_too_small_for_window_span_rejected_at_build():
    """A sliding program whose per-instant open-window count exceeds
    n_slots must fail at build(), not on the first event."""
    with pytest.raises(ValueError, match="n_slots"):
        _build("ring-small", window_slide=5.0, n_slots=8)
    # same span fits with a big enough ring
    _build("ring-fits", window_slide=5.0, n_slots=11)


def test_key_space_overflow_raises():
    events = [(float(i), f"key-{i}", 1.0) for i in range(20)]
    built = _build("ovf", window_size=100.0, batch_records=10)
    coord = StreamingCoordinator(MemoryStore(), MetadataStore(),
                                 program=built)
    with pytest.raises(ValueError, match="num_buckets"):
        coord.run_stream(StreamSource.from_records(events, batch_records=10))


# ---------------------------------------------------------------------------
# Backpressure / autoscaling
# ---------------------------------------------------------------------------

def test_backlog_scaling_math():
    pool = ServerlessPool("s", AutoscalerConfig(max_scale=8, min_scale=0))
    assert pool.desired_scale_from_backlog(0) == 0
    assert pool.desired_scale_from_backlog(3) == 3
    assert pool.desired_scale_from_backlog(100) == 8
    assert pool.desired_scale_from_backlog(10, per_replica=4) == 3


def test_ensure_scale_prewarms():
    pool = ServerlessPool("s", AutoscalerConfig(max_scale=4))
    assert pool.ensure_scale(3) == 3
    assert pool.replicas() == 3
    assert pool.cold_starts == 3
    assert pool.ensure_scale(2) == 0        # never scales down
    assert pool.ensure_scale(99) == 1       # clamped to max_scale
    assert pool.replicas() == 4


def test_stream_scales_pool_from_lag():
    events = _synth_events(n=3000)
    built = _build("lag")
    coord = StreamingCoordinator(MemoryStore(), MetadataStore(),
                                 program=built)
    report = coord.run_stream(
        StreamSource.from_records(events, batch_records=100))
    # 30 announced batches → lag well above pool max at the start
    assert report.max_lag >= 10
    assert report.scale_events >= 1
    assert coord.pool_stats()["replicas"] == 4   # clamped to n_workers
