"""Property: a randomly generated program that planlint passes clean
never trips the runtime's capacity guards when actually driven — no
mid-stream "window ring full" ``LateEventError``, no group-buffer
``capacity_dropped``, no late drops on in-order input.  This is the
contract that makes PL001/PL003 worth gating on: clean means the stream
runs, not just that a heuristic stayed quiet."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic container: seeded-sampling shim
    from _hypothesis_compat import given, settings, strategies as st

import numpy as np

from repro.analysis import errors
from repro.analysis.planlint import min_slots_required
from repro.core import MemoryStore, MetadataStore
from repro.pipeline import Pipeline, Windowing
from repro.streaming import StreamSource, StreamingCoordinator

# each example compiles + drives a real streaming program: keep the
# sample small here, let CI's real hypothesis search wider
_PROPERTY_SETTINGS = settings(max_examples=6, deadline=None)


def _clean_program(size, slide, lateness, slack, grouped, n_events):
    """A single-chain streaming program sized so planlint has nothing to
    say: the ring gets the exact bound plus ``slack``, and group capacity
    covers both the per-micro-batch floor (PL003) and the worst whole-run
    window population."""
    w = Windowing.sliding(size, slide) if slide else Windowing.tumbling(size)
    n_slots = min_slots_required(size, slide, lateness) + slack
    reduce_kw = (dict(mode="group", capacity=max(32, n_events))
                 if grouped else {})
    return (Pipeline.from_source(batch_records=64).key_by()
            .window(w).reduce("max" if grouped else "sum", **reduce_kw)
            .sink("stream-output/")
            .build(num_buckets=8, n_workers=2, batch_records=64,
                   n_slots=n_slots, allowed_lateness=lateness,
                   job_id="prop"))


@_PROPERTY_SETTINGS
@given(st.integers(0, 1 << 30),   # event-stream seed
       st.integers(1, 3),         # window size: 10/20/30 s
       st.integers(0, 2),         # 0: tumbling, k: slide = size / 2k
       st.integers(0, 1),         # allowed_lateness: 0 or 5 s
       st.integers(0, 2),         # ring slack above the exact bound
       st.integers(0, 1))         # aggregate vs group mode
def test_planlint_clean_programs_run_without_capacity_trips(
        seed, size_sel, slide_sel, late_sel, slack, grouped):
    size = 10.0 * size_sel
    slide = size / (2 * slide_sel) if slide_sel else None
    lateness = 5.0 * late_sel

    rng = np.random.default_rng(seed)
    n = 200
    events = [(float(t), f"k{int(k)}", float(v))
              for t, k, v in zip(np.sort(rng.uniform(0, 6 * size, n)),
                                 rng.integers(0, 5, n),
                                 rng.uniform(0, 100, n))]

    built = _clean_program(size, slide, lateness, slack, bool(grouped), n)
    assert errors(built.check()) == []

    # in-order input + a clean plan: the drive must finish — an undersized
    # ring would raise LateEventError("window ring full") mid-batch here
    store = MemoryStore()
    coord = StreamingCoordinator(store, MetadataStore(), program=built)
    report = coord.run_stream(StreamSource.from_records(events,
                                                        batch_records=64))
    assert report.records_in == n
    assert report.late_dropped == 0
    assert report.capacity_dropped == 0
    assert report.windows_emitted > 0


def test_undersized_ring_is_exactly_what_planlint_rejects():
    """The contrapositive, pinned once: the same generator one slot below
    the bound is both a planlint error and a build-time rejection — the
    static check and the runtime guard share ``min_slots_required``."""
    need = min_slots_required(30.0, 7.5, 5.0)
    with pytest.raises(Exception, match=f"need >= {need}"):
        (Pipeline.from_source(batch_records=64).key_by()
         .window(Windowing.sliding(30.0, 7.5)).reduce("sum")
         .sink("out/")
         .build(num_buckets=8, n_workers=2, batch_records=64,
                n_slots=need - 1, allowed_lateness=5.0, job_id="contra"))
